//! [`CpuBackend`] — real, artifact-free execution on the host CPU.
//!
//! The third [`Backend`](crate::engine::Backend) implementation: where
//! `PjrtBackend` needs AOT-compiled artifacts and `SimBackend` only
//! *models* time, this backend actually computes every tensor with the
//! native f32 kernels in [`super::kernels`]:
//!
//! * **Baseline path** (`plan: None`) — breadth-first, one whole-tensor
//!   kernel per layer, every intermediate allocated and round-tripped
//!   through main memory: the eager execution model of PyTorch the
//!   paper benchmarks against.
//! * **Optimized path** — plan segments: collapsed stacks run through
//!   the depth-first band walker ([`super::walker`], two ping-pong band
//!   buffers, `std::thread::scope` band parallelism), branch regions
//!   execute depth-first arm-by-arm exactly like the PJRT executor, and
//!   everything else falls back to the per-layer kernels.
//!
//! Both paths share the remaining-consumer bookkeeping scheme of
//! [`crate::scheduler::Executor`]: activations live in the value map as
//! `Arc<HostTensor>`, so fan-out nodes (residual/concat skip planes)
//! are reference-shared, never deep-copied.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::engine::{Backend, Workload};
use crate::graph::{Graph, Layer, NodeId};
use crate::obs::{ObsCtx, SpanKind};
use crate::optimizer::{OpKind, Plan, Segment, Stack};
use crate::runtime::{stack_exec_name, HostTensor, ParamStore};
use crate::scheduler::executor::take_value;
use crate::scheduler::ExecStats;

use super::{kernels, walker};

/// Native CPU execution of one graph + seed, with `threads` scoped
/// workers per kernel / band grid.
pub struct CpuBackend {
    graph: Arc<Graph>,
    seed: u64,
    threads: usize,
    params: ParamStore,
    /// Arc-wrapped raw parameters (weights / biases) by node and kind:
    /// the `ParamStore` hands out owned tensors, so without this layer
    /// every `run` would memcpy the network's whole parameter set.
    param_cache: HashMap<(NodeId, &'static str), Arc<HostTensor>>,
    /// Arc-wrapped folded-BN (scale, shift) pairs by node — repeated
    /// stack executions share the buffers instead of cloning them.
    bn_cache: HashMap<NodeId, (Arc<HostTensor>, Arc<HostTensor>)>,
    /// Remaining-consumer counts template (computed once).
    consumers: Vec<usize>,
}

/// Arc-cached raw parameter lookup. A free function over the two cache
/// fields (not a `&mut self` method) so callers can hold a borrow of
/// the backend's graph at the same time.
fn cached_param(
    cache: &mut HashMap<(NodeId, &'static str), Arc<HostTensor>>,
    params: &mut ParamStore,
    id: NodeId,
    want: &'static str,
) -> Arc<HostTensor> {
    if let Some(t) = cache.get(&(id, want)) {
        return t.clone();
    }
    let t = Arc::new(params.raw(id, want));
    cache.insert((id, want), t.clone());
    t
}

/// Record one span on the executing thread's row when tracing is
/// armed. The `None` branch is the whole disabled path: no clock read,
/// no lock, no allocation.
fn span(obs: Option<&ObsCtx>, kind: SpanKind, label: &str, t0: Instant) {
    if let Some(o) = obs {
        o.obs.spans.thread("cpu-exec").record(kind, label, o.trace, t0);
    }
}

/// Span label flavor of one top-level plan segment — matches the
/// `kind` column of [`crate::memsim::predicted_segments`], so the
/// drift report's join sees the same taxonomy on both sides.
fn segment_kind(graph: &Graph, seg: &Segment) -> &'static str {
    match seg {
        Segment::Single(id) => graph.node(*id).layer.kind_name(),
        Segment::Stack(_) => "stack",
        Segment::Branch { .. } => "branch",
    }
}

/// Arc-cached folded-BN (scale, shift) lookup; same shape as
/// [`cached_param`].
fn cached_bn(
    cache: &mut HashMap<NodeId, (Arc<HostTensor>, Arc<HostTensor>)>,
    params: &mut ParamStore,
    id: NodeId,
) -> (Arc<HostTensor>, Arc<HostTensor>) {
    if let Some(pair) = cache.get(&id) {
        return pair.clone();
    }
    let (s, b) = params.bn_folded(id);
    let pair = (Arc::new(s), Arc::new(b));
    cache.insert(id, pair.clone());
    pair
}

impl CpuBackend {
    pub fn new(graph: Arc<Graph>, seed: u64, threads: usize) -> Self {
        let cons = graph.consumer_map();
        let consumers = (0..graph.nodes.len()).map(|i| cons.count(i)).collect();
        let params = ParamStore::new(graph.clone(), seed);
        CpuBackend {
            graph,
            seed,
            threads: threads.max(1),
            params,
            param_cache: HashMap::new(),
            bn_cache: HashMap::new(),
            consumers,
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Change the worker-thread count for subsequent runs. Parameter
    /// and folded-BN caches are untouched (they are thread-agnostic),
    /// which is what makes the autotuner's thread sweep cheap.
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = threads.max(1);
    }

    /// Execute one non-stacked layer with the breadth-first kernels.
    fn run_node(
        &mut self,
        values: &mut HashMap<NodeId, Arc<HostTensor>>,
        remaining: &mut [usize],
        id: NodeId,
        stats: &mut ExecStats,
        obs: Option<&ObsCtx>,
    ) -> Result<()> {
        let node = self.graph.node(id);
        let name = format!("cpu:{}", node.name);
        let kind = node.layer.kind_name();
        let optimizable = node.layer.is_optimizable();
        let t0 = Instant::now();
        let out: HostTensor = match &node.layer {
            Layer::Input { .. } => unreachable!("input node is pre-seeded"),
            Layer::Dropout { .. } => {
                // Identity at inference: share the Arc, no copy.
                let x = take_value(values, remaining, node.inputs[0])?;
                span(obs, SpanKind::Kernel, &name, t0);
                stats.push(name, kind.into(), t0.elapsed().as_secs_f64(), optimizable);
                values.insert(id, x);
                return Ok(());
            }
            Layer::Flatten => {
                let x = take_value(values, remaining, node.inputs[0])?;
                Arc::unwrap_or_clone(x).reshape(node.shape.clone())
            }
            Layer::Conv2d { window, bias, .. } => {
                let x = take_value(values, remaining, node.inputs[0])?;
                let w = cached_param(&mut self.param_cache, &mut self.params, id, "weight");
                let b = if *bias {
                    Some(cached_param(&mut self.param_cache, &mut self.params, id, "bias"))
                } else {
                    None
                };
                kernels::conv2d(&x, &w, b.as_deref(), window, &node.shape, self.threads)
            }
            Layer::Linear { bias, .. } => {
                let x = take_value(values, remaining, node.inputs[0])?;
                let w = cached_param(&mut self.param_cache, &mut self.params, id, "weight");
                let b = if *bias {
                    Some(cached_param(&mut self.param_cache, &mut self.params, id, "bias"))
                } else {
                    None
                };
                kernels::linear(&x, &w, b.as_deref(), &node.shape, self.threads)
            }
            Layer::Pool2d {
                kind: pk,
                window,
                count_include_pad,
                ..
            } => {
                let x = take_value(values, remaining, node.inputs[0])?;
                kernels::pool2d(&x, *pk, window, *count_include_pad, &node.shape, self.threads)
            }
            Layer::AdaptiveAvgPool { out_hw } => {
                let x = take_value(values, remaining, node.inputs[0])?;
                kernels::adaptive_avg_pool(&x, *out_hw, &node.shape, self.threads)
            }
            Layer::BatchNorm2d { .. } => {
                let x = take_value(values, remaining, node.inputs[0])?;
                let (s, b) = cached_bn(&mut self.bn_cache, &mut self.params, id);
                kernels::bn_affine(&x, &s, &b, self.threads)
            }
            Layer::Relu => {
                let x = take_value(values, remaining, node.inputs[0])?;
                kernels::relu(&x, self.threads)
            }
            Layer::Add => {
                let a = take_value(values, remaining, node.inputs[0])?;
                let b = take_value(values, remaining, node.inputs[1])?;
                kernels::add(&a, &b)
            }
            Layer::Concat => {
                let xs: Vec<Arc<HostTensor>> = node
                    .inputs
                    .iter()
                    .map(|&i| take_value(values, remaining, i))
                    .collect::<Result<_>>()?;
                let refs: Vec<&HostTensor> = xs.iter().map(|a| a.as_ref()).collect();
                kernels::concat(&refs, &node.shape)
            }
        };
        span(obs, SpanKind::Kernel, &name, t0);
        stats.push(name, kind.into(), t0.elapsed().as_secs_f64(), optimizable);
        values.insert(id, Arc::new(out));
        Ok(())
    }

    /// Execute a collapsed stack through the depth-first band walker.
    fn run_stack(
        &mut self,
        values: &mut HashMap<NodeId, Arc<HostTensor>>,
        remaining: &mut [usize],
        stack: &Stack,
        stats: &mut ExecStats,
        obs: Option<&ObsCtx>,
    ) -> Result<()> {
        let t0 = Instant::now();
        let entry = self.graph.node(stack.nodes[0]).inputs[0];
        let x = take_value(values, remaining, entry)?;
        // Folded-BN (scale, shift) per bn op — Arc handles from the
        // backend cache, so repeated stack executions share buffers
        // instead of re-copying them.
        let mut bn: HashMap<NodeId, (Arc<HostTensor>, Arc<HostTensor>)> = HashMap::new();
        for seq in &stack.sequences {
            for step in &seq.steps {
                for op in &step.ops {
                    if matches!(op.kind, OpKind::BnAffine { .. }) {
                        bn.insert(
                            op.node,
                            cached_bn(&mut self.bn_cache, &mut self.params, op.node),
                        );
                    }
                }
            }
        }
        let out = walker::run_stack(stack, &x, &bn, self.threads, obs);
        // Interior nodes were never materialized; their consumers are
        // all internal to the stack.
        let last = *stack
            .nodes
            .last()
            .expect("plan verifier rejects empty stacks");
        for &nid in &stack.nodes {
            if nid != last {
                remaining[nid] = 0;
            }
        }
        stats.push(
            stack_exec_name(stack),
            "stack".into(),
            t0.elapsed().as_secs_f64(),
            true,
        );
        values.insert(last, Arc::new(out));
        Ok(())
    }

    /// Execute one plan segment (branch regions depth-first arm-by-arm,
    /// mirroring [`crate::scheduler::Executor`]).
    fn run_segment(
        &mut self,
        values: &mut HashMap<NodeId, Arc<HostTensor>>,
        remaining: &mut [usize],
        seg: &Segment,
        stats: &mut ExecStats,
        obs: Option<&ObsCtx>,
    ) -> Result<()> {
        match seg {
            Segment::Single(id) => self.run_node(values, remaining, *id, stats, obs),
            Segment::Stack(st) => self.run_stack(values, remaining, st, stats, obs),
            Segment::Branch { arms, join } => {
                for (a, arm) in arms.iter().enumerate() {
                    let t0 = obs.is_some().then(Instant::now);
                    for seg in arm {
                        self.run_segment(values, remaining, seg, stats, obs)?;
                    }
                    if let Some(t0) = t0 {
                        span(obs, SpanKind::BranchArm, &format!("arm{a}"), t0);
                    }
                }
                self.run_node(values, remaining, *join, stats, obs)
            }
        }
    }

    fn run_baseline(
        &mut self,
        input: HostTensor,
        obs: Option<&ObsCtx>,
    ) -> Result<(HostTensor, ExecStats)> {
        let t0 = obs.is_some().then(Instant::now);
        let mut stats = ExecStats::default();
        let mut values = HashMap::new();
        let mut remaining = self.consumers.clone();
        values.insert(0usize, Arc::new(input));
        for id in 1..self.graph.nodes.len() {
            self.run_node(&mut values, &mut remaining, id, &mut stats, obs)?;
        }
        if let Some(t0) = t0 {
            span(obs, SpanKind::Plan, "baseline", t0);
        }
        self.finish(values, stats)
    }

    fn run_plan(
        &mut self,
        plan: &Plan,
        input: HostTensor,
        obs: Option<&ObsCtx>,
    ) -> Result<(HostTensor, ExecStats)> {
        let t_plan = obs.is_some().then(Instant::now);
        let mut stats = ExecStats::default();
        let mut values = HashMap::new();
        let mut remaining = self.consumers.clone();
        values.insert(0usize, Arc::new(input));
        for (i, seg) in plan.segments.iter().enumerate() {
            let t0 = obs.is_some().then(Instant::now);
            self.run_segment(&mut values, &mut remaining, seg, &mut stats, obs)?;
            if let Some(t0) = t0 {
                // `seg{i}` is the drift-report join key
                // ([`crate::obs::drift`]); the flavor after ':' is
                // cosmetic.
                let label = format!("seg{i}:{}", segment_kind(&self.graph, seg));
                span(obs, SpanKind::Segment, &label, t0);
            }
        }
        if let Some(t0) = t_plan {
            span(obs, SpanKind::Plan, "plan", t0);
        }
        self.finish(values, stats)
    }

    fn finish(
        &self,
        mut values: HashMap<NodeId, Arc<HostTensor>>,
        stats: ExecStats,
    ) -> Result<(HostTensor, ExecStats)> {
        let out = values
            .remove(&self.graph.output)
            .ok_or_else(|| anyhow!("output not computed"))?;
        Ok((Arc::unwrap_or_clone(out), stats))
    }
}

impl Backend for CpuBackend {
    fn name(&self) -> &'static str {
        "cpu"
    }

    fn set_threads(&mut self, threads: usize) -> bool {
        CpuBackend::set_threads(self, threads);
        true
    }

    fn run(&mut self, work: &Workload, input: HostTensor) -> Result<(HostTensor, ExecStats)> {
        anyhow::ensure!(
            Arc::ptr_eq(&work.graph, &self.graph),
            "CpuBackend is bound to graph '{}'; rebuild the backend for a different network",
            self.graph.name
        );
        anyhow::ensure!(
            work.seed == self.seed,
            "CpuBackend is bound to seed {}; workload asks for {}",
            self.seed,
            work.seed
        );
        match &work.plan {
            Some(p) => self.run_plan(p, input, work.obs.as_ref()),
            None => self.run_baseline(input, work.obs.as_ref()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench;
    use crate::device::DeviceSpec;
    use crate::optimizer::{optimize, CollapseOptions};
    use crate::rng::ParamKind;

    fn workload(graph: Arc<Graph>, plan: Option<Arc<Plan>>, seed: u64) -> Workload {
        Workload {
            graph,
            plan,
            seed,
            obs: None,
        }
    }

    #[test]
    fn depth_first_plan_matches_breadth_first_bitwise() {
        // A fully-optimizable block net: the whole network collapses
        // into one stack, so the plan path is 100% walker.
        let graph = Arc::new(bench::block_net(3, 2, 4, 16));
        let plan = Arc::new(optimize(
            &graph,
            &DeviceSpec::host_cpu(),
            &CollapseOptions::default(),
        ));
        plan.validate(&graph).unwrap();
        let input = HostTensor::from_seed(
            graph.input_shape().clone(),
            42,
            ParamKind::Activation,
        );
        let mut be = CpuBackend::new(graph.clone(), 9, 2);
        let (base, stats_base) =
            be.run(&workload(graph.clone(), None, 9), input.clone()).unwrap();
        let (df, stats_df) = be.run(&workload(graph.clone(), Some(plan), 9), input).unwrap();
        assert_eq!(base, df, "schedules diverge");
        assert_eq!(base.shape, *graph.output_shape());
        assert_eq!(stats_base.segments.len(), graph.num_layers());
        assert!(stats_df.segments.iter().any(|s| s.kind == "stack"));
    }

    #[test]
    fn traced_plan_run_records_nested_spans() {
        let graph = Arc::new(bench::block_net(2, 1, 2, 12));
        let plan = Arc::new(optimize(
            &graph,
            &DeviceSpec::host_cpu(),
            &CollapseOptions::default(),
        ));
        plan.validate(&graph).unwrap();
        let input = HostTensor::from_seed(graph.input_shape().clone(), 1, ParamKind::Activation);
        let obs = Arc::new(crate::obs::Obs::default());
        let mut be = CpuBackend::new(graph.clone(), 5, 2);
        let mut work = workload(graph.clone(), Some(plan.clone()), 5);
        work.obs = Some(ObsCtx {
            obs: obs.clone(),
            trace: 0xAB,
        });
        be.run(&work, input).unwrap();
        let spans = obs.spans.drain();
        assert!(spans.iter().any(|s| s.kind == SpanKind::Plan));
        let segs: Vec<_> = spans.iter().filter(|s| s.kind == SpanKind::Segment).collect();
        assert_eq!(segs.len(), plan.segments.len(), "one span per top-level segment");
        assert!(segs.iter().all(|s| s.trace == 0xAB && s.label.starts_with("seg")));
        assert!(
            spans.iter().any(|s| s.kind == SpanKind::Band),
            "the collapsed stack must record band spans"
        );
        // Untraced runs leave the recorder untouched.
        let input2 = HostTensor::from_seed(graph.input_shape().clone(), 1, ParamKind::Activation);
        be.run(&workload(graph.clone(), None, 5), input2).unwrap();
        assert!(obs.spans.drain().is_empty());
    }

    #[test]
    fn rejects_foreign_graph_and_seed() {
        let graph = Arc::new(bench::block_net(1, 1, 2, 8));
        let other = Arc::new(bench::block_net(1, 1, 2, 8));
        let input = HostTensor::from_seed(
            graph.input_shape().clone(),
            1,
            ParamKind::Activation,
        );
        let mut be = CpuBackend::new(graph.clone(), 5, 1);
        assert!(be.run(&workload(other, None, 5), input.clone()).is_err());
        assert!(be.run(&workload(graph, None, 6), input).is_err());
    }
}
