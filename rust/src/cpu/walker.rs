//! The depth-first stack walker: real execution of collapsed
//! [`Stack`]s, one cache-sized band at a time (§4.1 Figure 9, §4.4).
//!
//! A sequence's band grid is `(batch · channels) × n_bands` — exactly
//! the grid the collapser sizes `tile_rows` for. Each work item is one
//! band of one (batch, channel) plane: the walker back-propagates the
//! band's row interval through every op (pool halos grow it, clamped to
//! the tensor extent — the same arithmetic as
//! [`Sequence::in_rows_for`]), then streams the band through the whole
//! op chain using **two ping-pong band buffers** that never leave the
//! fast tier. The first op reads straight from the input tensor and the
//! last op writes straight into the output tensor, so a band makes
//! exactly one main-memory round trip regardless of stack depth — the
//! paper's depth-first locality, for real this time.
//!
//! Independent bands run on `std::thread::scope` workers
//! ([`crate::cpu::par::run_items`]): each worker owns its buffer pair
//! and processes a contiguous slice of the band grid. Sequences
//! synchronize through main memory (materialized tensors), mirroring
//! the paper's sequence semantics.
//!
//! Numerics: element-wise ops and [`pool_window`] are shared with the
//! breadth-first kernels, so depth-first output is *bit-identical* to
//! the baseline schedule.

use std::collections::HashMap;
use std::sync::Arc;

use crate::graph::{NodeId, PoolKind, Window2d};
use crate::obs::{ObsCtx, SpanKind};
use crate::optimizer::{OpKind, Operation, Sequence, Stack};
use crate::runtime::HostTensor;

use super::kernels::pool_window;
use super::par::run_items;

/// One stack operation lowered for band execution.
enum BandOp<'a> {
    /// Folded batch-norm: `y = x * scale[c] + shift[c]`.
    Affine { scale: &'a [f32], shift: &'a [f32] },
    Relu,
    /// Inference-mode dropout.
    Identity,
    Pool {
        kind: PoolKind,
        window: Window2d,
        count_include_pad: bool,
        /// Full input-plane extent (for halo clamping and -inf/divisor
        /// edge handling).
        in_h: usize,
        in_w: usize,
        out_w: usize,
    },
}

fn lower<'a>(
    op: &Operation,
    bn: &'a HashMap<NodeId, (Arc<HostTensor>, Arc<HostTensor>)>,
) -> BandOp<'a> {
    match &op.kind {
        OpKind::BnAffine { .. } => {
            let (s, b) = bn
                .get(&op.node)
                .expect("folded bn params gathered for every bn op");
            BandOp::Affine {
                scale: &s.data,
                shift: &b.data,
            }
        }
        OpKind::Relu => BandOp::Relu,
        OpKind::Identity => BandOp::Identity,
        OpKind::Pool {
            kind,
            window,
            count_include_pad,
            ..
        } => BandOp::Pool {
            kind: *kind,
            window: *window,
            count_include_pad: *count_include_pad,
            in_h: op.in_shape.height(),
            in_w: op.in_shape.width(),
            out_w: op.out_shape.width(),
        },
    }
}

/// Input-row interval required to produce output rows `[out_lo, out_hi)`
/// of `op` — the per-op form of [`Sequence::in_rows_for`]'s clamped halo
/// back-propagation.
fn in_interval(op: &BandOp, out_lo: usize, out_hi: usize) -> (usize, usize) {
    match op {
        BandOp::Pool { window, in_h, .. } => {
            let (k, s) = (window.kernel.0, window.stride.0);
            let p = window.pad.0;
            let lo = (out_lo * s).saturating_sub(p);
            let hi = ((out_hi - 1) * s + k).saturating_sub(p).min(*in_h);
            (lo.min(hi), hi)
        }
        _ => (out_lo, out_hi),
    }
}

/// Apply an element-wise op from `src` into `dst` (same geometry).
/// `chan = Some(c)`: rank-4 plane of channel `c` (scalar affine);
/// `chan = None`: rank-2 rows of `width` features (per-column affine).
fn elem_copy(op: &BandOp, chan: Option<usize>, width: usize, src: &[f32], dst: &mut [f32]) {
    debug_assert_eq!(src.len(), dst.len());
    match op {
        BandOp::Relu => {
            for (d, &v) in dst.iter_mut().zip(src) {
                *d = v.max(0.0);
            }
        }
        BandOp::Identity => dst.copy_from_slice(src),
        BandOp::Affine { scale, shift } => match chan {
            Some(c) => {
                let (s, b) = (scale[c], shift[c]);
                for (d, &v) in dst.iter_mut().zip(src) {
                    *d = v * s + b;
                }
            }
            None => {
                for (row_d, row_s) in dst.chunks_mut(width).zip(src.chunks(width)) {
                    for (((d, &v), &s), &b) in
                        row_d.iter_mut().zip(row_s).zip(scale.iter()).zip(shift.iter())
                    {
                        *d = v * s + b;
                    }
                }
            }
        },
        BandOp::Pool { .. } => unreachable!("pool is not element-wise"),
    }
}

/// In-place variant of [`elem_copy`] for mid-chain ops (the band stays
/// in its fast-tier buffer).
fn elem_inplace(op: &BandOp, chan: Option<usize>, width: usize, buf: &mut [f32]) {
    match op {
        BandOp::Relu => {
            for v in buf.iter_mut() {
                *v = v.max(0.0);
            }
        }
        BandOp::Identity => {}
        BandOp::Affine { scale, shift } => match chan {
            Some(c) => {
                let (s, b) = (scale[c], shift[c]);
                for v in buf.iter_mut() {
                    *v = *v * s + b;
                }
            }
            None => {
                for row in buf.chunks_mut(width) {
                    for ((v, &s), &b) in row.iter_mut().zip(scale.iter()).zip(shift.iter()) {
                        *v = *v * s + b;
                    }
                }
            }
        },
        BandOp::Pool { .. } => unreachable!("pool is not element-wise"),
    }
}

/// Pool output rows `[out_lo, out_hi)` from a source holding input rows
/// starting at absolute row `src_row0` into `dst`.
fn pool_to(
    op: &BandOp,
    src: &[f32],
    src_row0: usize,
    dst: &mut [f32],
    out_lo: usize,
    out_hi: usize,
) {
    let BandOp::Pool {
        kind,
        window,
        count_include_pad,
        in_h,
        in_w,
        out_w,
    } = op
    else {
        unreachable!("pool_to on non-pool op")
    };
    debug_assert_eq!(dst.len(), (out_hi - out_lo) * out_w);
    for (oy, dst_row) in (out_lo..out_hi).zip(dst.chunks_mut(*out_w)) {
        for (ox, v) in dst_row.iter_mut().enumerate() {
            *v = pool_window(
                *kind,
                window,
                *count_include_pad,
                src,
                src_row0,
                *in_h,
                *in_w,
                oy,
                ox,
            );
        }
    }
}

/// Execute one collapsed sequence depth-first over its band grid.
///
/// `bn` maps each `BnAffine` op's graph node to its folded
/// (scale, shift) pair (see `ParamStore::bn_folded`).
///
/// `obs`: when armed, every band work item records a
/// [`SpanKind::Band`] span on its worker's thread row — per-worker
/// [`crate::obs::ThreadSpans`] handles live in the scratch state, so
/// recording stays lock-local. `None` takes the literal pre-obs path:
/// no clock reads, no allocation.
pub fn run_sequence(
    seq: &Sequence,
    input: &HostTensor,
    bn: &HashMap<NodeId, (Arc<HostTensor>, Arc<HostTensor>)>,
    threads: usize,
    obs: Option<&ObsCtx>,
) -> HostTensor {
    debug_assert_eq!(&input.shape, seq.in_shape());
    let raw_ops: Vec<&Operation> = seq.steps.iter().flat_map(|s| &s.ops).collect();
    let ops: Vec<BandOp> = raw_ops.iter().map(|o| lower(o, bn)).collect();
    let out_shape = seq.out_shape().clone();
    let in_shape = seq.in_shape();
    let rank4 = out_shape.rank() == 4;
    // Band geometry: rank-4 tensors band over H within one (batch,
    // channel) plane; rank-2 over the batch dimension (one plane).
    let (out_rows, out_w, channels) = if rank4 {
        (
            out_shape.height(),
            out_shape.width(),
            out_shape.channels(),
        )
    } else {
        (out_shape.batch(), out_shape.channels(), out_shape.channels())
    };
    let (in_rows, in_w) = if rank4 {
        (in_shape.height(), in_shape.width())
    } else {
        (in_shape.batch(), in_shape.channels())
    };
    // Per-op row widths (elements per band row entering / leaving).
    let widths: Vec<(usize, usize)> = raw_ops
        .iter()
        .map(|o| {
            if rank4 {
                (o.in_shape.width(), o.out_shape.width())
            } else {
                (o.in_shape.channels(), o.out_shape.channels())
            }
        })
        .collect();
    let tile = seq.tile_rows.max(1).min(out_rows);
    let mut out = HostTensor::zeros(out_shape.clone());

    // The band grid: one item per (plane, band) — disjoint &mut slices
    // of the output tensor, handed to scoped workers.
    let plane_len = out_rows * out_w;
    let mut items: Vec<(usize, usize, &mut [f32])> = Vec::new();
    for (p, plane) in out.data.chunks_mut(plane_len).enumerate() {
        let mut rest = plane;
        let mut lo = 0usize;
        while lo < out_rows {
            let hi = (lo + tile).min(out_rows);
            let (band, tail) = rest.split_at_mut((hi - lo) * out_w);
            items.push((p, lo, band));
            rest = tail;
            lo = hi;
        }
    }

    let in_plane_len = in_rows * in_w;
    let input_data = &input.data;
    let k = ops.len();
    let trace = obs.map_or(0, |o| o.trace);
    run_items(
        threads,
        items,
        || {
            (
                Vec::<f32>::new(),
                Vec::<f32>::new(),
                Vec::<(usize, usize)>::new(),
                obs.map(|o| o.obs.spans.thread("band-worker")),
            )
        },
        |(p, lo, mut band), (buf_a, buf_b, iv, ts)| {
            let t0 = ts.is_some().then(std::time::Instant::now);
            let chan = if rank4 { Some(p % channels) } else { None };
            let hi = lo + band.len() / out_w;
            // Halo back-propagation: iv[i] = rows entering op i,
            // iv[k] = this band's output rows.
            iv.clear();
            iv.resize(k + 1, (0usize, 0usize));
            iv[k] = (lo, hi);
            for i in (0..k).rev() {
                iv[i] = in_interval(&ops[i], iv[i + 1].0, iv[i + 1].1);
            }
            let plane_src = &input_data[p * in_plane_len..][..in_plane_len];
            // Stream the band through the chain: op 0 reads the input
            // tensor, op k-1 writes the output band, everything between
            // ping-pongs across the two band buffers.
            let mut cur_in_a = true;
            for i in 0..k {
                let first = i == 0;
                let last = i == k - 1;
                let (w_in, w_out) = widths[i];
                let (in_lo, in_hi) = iv[i];
                let (o_lo, o_hi) = iv[i + 1];
                match &ops[i] {
                    op @ BandOp::Pool { .. } => {
                        if first && last {
                            pool_to(op, plane_src, 0, &mut *band, o_lo, o_hi);
                        } else if first {
                            buf_a.clear();
                            buf_a.resize((o_hi - o_lo) * w_out, 0.0);
                            pool_to(op, plane_src, 0, buf_a, o_lo, o_hi);
                            cur_in_a = true;
                        } else if last {
                            let src: &[f32] =
                                if cur_in_a { buf_a.as_slice() } else { buf_b.as_slice() };
                            pool_to(op, src, in_lo, &mut *band, o_lo, o_hi);
                        } else if cur_in_a {
                            buf_b.clear();
                            buf_b.resize((o_hi - o_lo) * w_out, 0.0);
                            pool_to(op, buf_a, in_lo, buf_b, o_lo, o_hi);
                            cur_in_a = false;
                        } else {
                            buf_a.clear();
                            buf_a.resize((o_hi - o_lo) * w_out, 0.0);
                            pool_to(op, buf_b, in_lo, buf_a, o_lo, o_hi);
                            cur_in_a = true;
                        }
                    }
                    op => {
                        if first && last {
                            elem_copy(
                                op,
                                chan,
                                w_in,
                                &plane_src[in_lo * w_in..in_hi * w_in],
                                &mut *band,
                            );
                        } else if first {
                            buf_a.clear();
                            buf_a.resize((in_hi - in_lo) * w_in, 0.0);
                            elem_copy(
                                op,
                                chan,
                                w_in,
                                &plane_src[in_lo * w_in..in_hi * w_in],
                                buf_a,
                            );
                            cur_in_a = true;
                        } else if last {
                            let src: &[f32] =
                                if cur_in_a { buf_a.as_slice() } else { buf_b.as_slice() };
                            elem_copy(op, chan, w_in, src, &mut *band);
                        } else {
                            let buf: &mut Vec<f32> =
                                if cur_in_a { &mut *buf_a } else { &mut *buf_b };
                            elem_inplace(op, chan, w_in, buf);
                        }
                    }
                }
            }
            if let (Some(ts), Some(t0)) = (ts.as_ref(), t0) {
                ts.record(SpanKind::Band, "band", trace, t0);
            }
        },
    );
    out
}

/// Execute a whole collapsed stack: sequences in order, each banded
/// depth-first, synchronizing through materialized tensors at sequence
/// boundaries.
pub fn run_stack(
    stack: &Stack,
    input: &HostTensor,
    bn: &HashMap<NodeId, (Arc<HostTensor>, Arc<HostTensor>)>,
    threads: usize,
    obs: Option<&ObsCtx>,
) -> HostTensor {
    let mut cur: Option<HostTensor> = None;
    for seq in &stack.sequences {
        let next = run_sequence(seq, cur.as_ref().unwrap_or(input), bn, threads, obs);
        cur = Some(next);
    }
    cur.expect("stack has at least one sequence")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceSpec;
    use crate::graph::{Layer, PoolKind, Shape, Window2d};
    use crate::optimizer::{collapse, CollapseOptions};
    use crate::rng::ParamKind;

    /// Build the op chain for a spec of layer tags, threading shapes.
    fn mk_ops(spec: &[&str], shape: Shape) -> Vec<Operation> {
        let mut ops = Vec::new();
        let mut cur = shape;
        for (i, tag) in spec.iter().enumerate() {
            let layer = match *tag {
                "bn" => Layer::BatchNorm2d { eps: 1e-5 },
                "relu" => Layer::Relu,
                "id" => Layer::Dropout { p: 0.5 },
                "max3s1p1" => Layer::Pool2d {
                    kind: PoolKind::Max,
                    window: Window2d::square(3, 1, 1),
                    ceil_mode: false,
                    count_include_pad: true,
                },
                "max2s2" => Layer::Pool2d {
                    kind: PoolKind::Max,
                    window: Window2d::square(2, 2, 0),
                    ceil_mode: false,
                    count_include_pad: true,
                },
                "avg3s2p1" => Layer::Pool2d {
                    kind: PoolKind::Avg,
                    window: Window2d::square(3, 2, 1),
                    ceil_mode: false,
                    count_include_pad: true,
                },
                "avg2s2nip" => Layer::Pool2d {
                    kind: PoolKind::Avg,
                    window: Window2d::square(2, 2, 1),
                    ceil_mode: false,
                    count_include_pad: false,
                },
                other => panic!("unknown {other}"),
            };
            let out = layer.infer_shape(&[&cur]).unwrap();
            ops.push(
                Operation::from_layer(i + 1, &format!("op{i}"), &layer, &cur, &out).unwrap(),
            );
            cur = out;
        }
        ops
    }

    /// Breadth-first reference: whole-tensor kernels, op by op.
    fn reference(
        ops: &[Operation],
        input: &HostTensor,
        bn: &HashMap<NodeId, (Arc<HostTensor>, Arc<HostTensor>)>,
    ) -> HostTensor {
        use super::super::kernels;
        let mut cur = input.clone();
        for op in ops {
            cur = match &op.kind {
                OpKind::BnAffine { .. } => {
                    let (s, b) = &bn[&op.node];
                    kernels::bn_affine(&cur, s, b, 1)
                }
                OpKind::Relu => kernels::relu(&cur, 1),
                OpKind::Identity => cur,
                OpKind::Pool {
                    kind,
                    window,
                    count_include_pad,
                    ..
                } => kernels::pool2d(
                    &cur,
                    *kind,
                    window,
                    *count_include_pad,
                    &op.out_shape,
                    1,
                ),
            };
        }
        cur
    }

    fn bn_params(
        ops: &[Operation],
        seed: u64,
    ) -> HashMap<NodeId, (Arc<HostTensor>, Arc<HostTensor>)> {
        let mut m = HashMap::new();
        for op in ops {
            if matches!(op.kind, OpKind::BnAffine { .. }) {
                let c = op.in_shape.channels();
                let shape = Shape::new(vec![c], op.in_shape.dtype);
                let s = HostTensor::from_seed(
                    shape.clone(),
                    seed ^ op.node as u64,
                    ParamKind::BnGamma,
                );
                let b = HostTensor::from_seed(
                    shape,
                    seed ^ ((op.node as u64) << 8),
                    ParamKind::BnBeta,
                );
                m.insert(op.node, (Arc::new(s), Arc::new(b)));
            }
        }
        m
    }

    fn run_collapsed(
        ops: &[Operation],
        input: &HostTensor,
        bn: &HashMap<NodeId, (Arc<HostTensor>, Arc<HostTensor>)>,
        budget: usize,
        threads: usize,
    ) -> HostTensor {
        let device = DeviceSpec {
            fast_mem_bytes: budget,
            ..DeviceSpec::paper_cpu()
        };
        let seqs = collapse(ops, &device, &CollapseOptions::default());
        let mut cur = input.clone();
        for seq in &seqs {
            cur = run_sequence(seq, &cur, bn, threads, None);
        }
        cur
    }

    #[test]
    fn banded_walk_matches_breadth_first_bitwise() {
        // Mixed element-wise + strided/padded pools, several budgets
        // (band heights) and thread counts: depth-first must be
        // bit-identical to the breadth-first reference.
        let specs: &[&[&str]] = &[
            &["relu"],
            &["bn", "relu"],
            &["max2s2"],
            &["bn", "relu", "max3s1p1"],
            &["max3s1p1", "bn", "relu", "max2s2", "relu"],
            &["avg3s2p1", "bn", "avg2s2nip", "relu"],
            &["bn", "relu", "id", "max3s1p1", "max3s1p1", "bn"],
        ];
        for (i, spec) in specs.iter().enumerate() {
            let shape = Shape::nchw(2, 3, 13, 11);
            let ops = mk_ops(spec, shape.clone());
            let input = HostTensor::from_seed(shape, 100 + i as u64, ParamKind::Activation);
            let bn = bn_params(&ops, 7);
            let want = reference(&ops, &input, &bn);
            for budget in [512usize, 2 * 1024, 1 << 20] {
                for threads in [1usize, 3] {
                    let got = run_collapsed(&ops, &input, &bn, budget, threads);
                    assert_eq!(
                        got, want,
                        "spec {i} budget {budget} threads {threads} diverged"
                    );
                }
            }
        }
    }

    #[test]
    fn rank2_bands_over_batch_rows() {
        // Classifier-head stack on (N, F): bn applies per column.
        let shape = Shape::nf(9, 5);
        let ops = mk_ops(&["bn", "relu", "id"], shape.clone());
        let input = HostTensor::from_seed(shape, 3, ParamKind::Activation);
        let bn = bn_params(&ops, 11);
        let want = reference(&ops, &input, &bn);
        for threads in [1usize, 2] {
            let got = run_collapsed(&ops, &input, &bn, 64, threads);
            assert_eq!(got, want, "threads {threads}");
        }
    }

    #[test]
    fn run_stack_chains_sequences_through_main_memory() {
        // A deep pool chain under a tiny budget splits into multiple
        // sequences; run_stack must still match the reference.
        let shape = Shape::nchw(1, 2, 24, 24);
        let ops = mk_ops(
            &["max3s1p1", "bn", "relu", "max3s1p1", "max3s1p1", "relu"],
            shape.clone(),
        );
        let input = HostTensor::from_seed(shape, 5, ParamKind::Activation);
        let bn = bn_params(&ops, 13);
        let want = reference(&ops, &input, &bn);
        let device = DeviceSpec {
            fast_mem_bytes: 1024,
            ..DeviceSpec::paper_cpu()
        };
        let sequences = collapse(&ops, &device, &CollapseOptions::default());
        assert!(sequences.len() > 1, "tiny budget must split sequences");
        let stack = Stack {
            nodes: ops.iter().map(|o| o.node).collect(),
            sequences,
            signature: "test".into(),
        };
        let got = run_stack(&stack, &input, &bn, 2, None);
        assert_eq!(got, want);
    }
}
