//! Breadth-first f32 CPU kernels — one function per graph layer.
//!
//! These are the native baseline path of [`crate::cpu::CpuBackend`]: the
//! eager, layer-at-a-time execution model of PyTorch (every layer reads
//! and writes its full tensor through main memory, every output is a
//! fresh allocation). Numerics follow `python/compile/layers.py`
//! (PyTorch semantics): floor/ceil window arithmetic, max-pool padding
//! with `-inf`, avg-pool `count_include_pad`, folded inference
//! batch-norm (`y = x * scale[c] + shift[c]`).
//!
//! [`pool_window`] is the single source of pooling arithmetic: the
//! depth-first band walker (`super::walker`) calls the same function per
//! band, so the two schedules agree *bitwise* on every stacked layer.
//!
//! Parallelism mirrors the paper's §5.2 fix of the Listing-4 bug: every
//! kernel iterates over `batch × channels` planes (not just batch), so
//! all `--threads` workers stay busy at batch 1.

use crate::graph::{PoolKind, Shape, Window2d};
use crate::runtime::HostTensor;

use super::par::for_planes;

/// Direct 2-D convolution: NCHW input, OIHW weights, optional bias.
/// Parallel over (batch, out_channel) output planes.
pub fn conv2d(
    x: &HostTensor,
    weight: &HostTensor,
    bias: Option<&HostTensor>,
    window: &Window2d,
    out_shape: &Shape,
    threads: usize,
) -> HostTensor {
    let (n, ci, in_h, in_w) = (
        x.shape.batch(),
        x.shape.channels(),
        x.shape.height(),
        x.shape.width(),
    );
    let (oc, out_h, out_w) = (out_shape.channels(), out_shape.height(), out_shape.width());
    debug_assert_eq!(out_shape.batch(), n);
    debug_assert_eq!(weight.shape.dims, vec![oc, ci, window.kernel.0, window.kernel.1]);
    let (kh, kw) = window.kernel;
    let (sh, sw) = window.stride;
    let (ph, pw) = window.pad;
    let mut out = HostTensor::zeros(out_shape.clone());
    let in_plane = in_h * in_w;
    for_planes(threads, &mut out.data, out_h * out_w, |plane, dst| {
        let b = plane / oc;
        let o = plane % oc;
        let bias_v = bias.map_or(0.0f32, |t| t.data[o]);
        for (oy, dst_row) in dst.chunks_mut(out_w).enumerate() {
            let iy0 = (oy * sh) as isize - ph as isize;
            for (ox, dst_v) in dst_row.iter_mut().enumerate() {
                let ix0 = (ox * sw) as isize - pw as isize;
                let mut acc = bias_v;
                for c in 0..ci {
                    let src = &x.data[(b * ci + c) * in_plane..][..in_plane];
                    let wbase = ((o * ci + c) * kh) * kw;
                    for ky in 0..kh {
                        let iy = iy0 + ky as isize;
                        if iy < 0 || iy >= in_h as isize {
                            continue;
                        }
                        let src_row = &src[iy as usize * in_w..][..in_w];
                        let w_row = &weight.data[wbase + ky * kw..][..kw];
                        for (kx, &wv) in w_row.iter().enumerate() {
                            let ix = ix0 + kx as isize;
                            if ix < 0 || ix >= in_w as isize {
                                continue;
                            }
                            acc += src_row[ix as usize] * wv;
                        }
                    }
                }
                *dst_v = acc;
            }
        }
    });
    out
}

/// Fully-connected layer: `(N, in) @ (in, out) + bias`, weights stored
/// `[in, out]` (the `ParamStore` layout). Parallel over batch rows.
pub fn linear(
    x: &HostTensor,
    weight: &HostTensor,
    bias: Option<&HostTensor>,
    out_shape: &Shape,
    threads: usize,
) -> HostTensor {
    let in_f = x.shape.channels();
    let out_f = out_shape.channels();
    debug_assert_eq!(weight.shape.dims, vec![in_f, out_f]);
    let mut out = HostTensor::zeros(out_shape.clone());
    for_planes(threads, &mut out.data, out_f, |row, dst| {
        match bias {
            Some(b) => dst.copy_from_slice(&b.data),
            None => dst.fill(0.0),
        }
        let x_row = &x.data[row * in_f..][..in_f];
        for (i, &xv) in x_row.iter().enumerate() {
            let w_row = &weight.data[i * out_f..][..out_f];
            for (d, &wv) in dst.iter_mut().zip(w_row) {
                *d += xv * wv;
            }
        }
    });
    out
}

/// One pooling window at output position `(oy, ox)`, evaluated against
/// a source buffer that holds input rows `[src_row0, src_row0 + ...)`
/// of a plane whose full extent is `in_h × in_w`.
///
/// Shared between the breadth-first kernel (whole plane, `src_row0 = 0`)
/// and the depth-first band walker (halo band), so both schedules
/// produce bit-identical pooling results. Max pooling treats padding as
/// `-inf` (clips to valid cells); average pooling divides by the window
/// ∩ padded-extent cell count (`count_include_pad`) or the valid cell
/// count otherwise.
#[allow(clippy::too_many_arguments)]
pub fn pool_window(
    kind: PoolKind,
    window: &Window2d,
    count_include_pad: bool,
    src: &[f32],
    src_row0: usize,
    in_h: usize,
    in_w: usize,
    oy: usize,
    ox: usize,
) -> f32 {
    let (kh, kw) = window.kernel;
    let (sh, sw) = window.stride;
    let (ph, pw) = window.pad;
    let ry0 = (oy * sh) as isize - ph as isize;
    let rx0 = (ox * sw) as isize - pw as isize;
    let y_lo = ry0.max(0) as usize;
    let y_hi = ((ry0 + kh as isize).min(in_h as isize)).max(0) as usize;
    let x_lo = rx0.max(0) as usize;
    let x_hi = ((rx0 + kw as isize).min(in_w as isize)).max(0) as usize;
    match kind {
        PoolKind::Max => {
            let mut m = f32::NEG_INFINITY;
            for y in y_lo..y_hi {
                let row = &src[(y - src_row0) * in_w..][..in_w];
                for &v in &row[x_lo..x_hi] {
                    m = m.max(v);
                }
            }
            m
        }
        PoolKind::Avg => {
            let mut sum = 0.0f32;
            for y in y_lo..y_hi {
                let row = &src[(y - src_row0) * in_w..][..in_w];
                for &v in &row[x_lo..x_hi] {
                    sum += v;
                }
            }
            let divisor = if count_include_pad {
                // Window ∩ padded extent [-p, in + p): k×k in floor mode,
                // clipped at the padded boundary in ceil mode.
                let rows = (ry0 + kh as isize).min(in_h as isize + ph as isize)
                    - ry0.max(-(ph as isize));
                let cols = (rx0 + kw as isize).min(in_w as isize + pw as isize)
                    - rx0.max(-(pw as isize));
                (rows * cols) as f32
            } else {
                ((y_hi - y_lo) * (x_hi - x_lo)) as f32
            };
            sum / divisor
        }
    }
}

/// Max/avg pooling over NCHW. Parallel over (batch, channel) planes.
pub fn pool2d(
    x: &HostTensor,
    kind: PoolKind,
    window: &Window2d,
    count_include_pad: bool,
    out_shape: &Shape,
    threads: usize,
) -> HostTensor {
    let (in_h, in_w) = (x.shape.height(), x.shape.width());
    let (out_h, out_w) = (out_shape.height(), out_shape.width());
    let in_plane = in_h * in_w;
    let mut out = HostTensor::zeros(out_shape.clone());
    for_planes(threads, &mut out.data, out_h * out_w, |plane, dst| {
        let src = &x.data[plane * in_plane..][..in_plane];
        for (oy, dst_row) in dst.chunks_mut(out_w).enumerate() {
            for (ox, v) in dst_row.iter_mut().enumerate() {
                *v = pool_window(kind, window, count_include_pad, src, 0, in_h, in_w, oy, ox);
            }
        }
    });
    out
}

/// Adaptive average pooling for dividing extents: a plain average pool
/// whose kernel and stride are `in / out` (exactly how
/// `python/compile/layers.py` computes the block mean).
pub fn adaptive_avg_pool(
    x: &HostTensor,
    out_hw: (usize, usize),
    out_shape: &Shape,
    threads: usize,
) -> HostTensor {
    let (in_h, in_w) = (x.shape.height(), x.shape.width());
    let window = Window2d {
        kernel: (in_h / out_hw.0, in_w / out_hw.1),
        stride: (in_h / out_hw.0, in_w / out_hw.1),
        pad: (0, 0),
    };
    pool2d(x, PoolKind::Avg, &window, true, out_shape, threads)
}

/// Folded inference batch-norm: `y = x * scale[c] + shift[c]`.
/// Rank-4 applies per (batch, channel) plane; rank-2 per feature column.
pub fn bn_affine(
    x: &HostTensor,
    scale: &HostTensor,
    shift: &HostTensor,
    threads: usize,
) -> HostTensor {
    let c = x.shape.channels();
    let rank4 = x.shape.rank() == 4;
    let chunk = if rank4 {
        x.shape.height() * x.shape.width()
    } else {
        c
    };
    let mut out = HostTensor::zeros(x.shape.clone());
    for_planes(threads, &mut out.data, chunk, |p, dst| {
        let src = &x.data[p * chunk..][..chunk];
        if rank4 {
            let (s, b) = (scale.data[p % c], shift.data[p % c]);
            for (d, &v) in dst.iter_mut().zip(src) {
                *d = v * s + b;
            }
        } else {
            for (((d, &v), &s), &b) in
                dst.iter_mut().zip(src).zip(&scale.data).zip(&shift.data)
            {
                *d = v * s + b;
            }
        }
    });
    out
}

/// Rectified linear unit (parallel over planes / rows like the rest of
/// the baseline kernels, so thread budgets stay comparable).
pub fn relu(x: &HostTensor, threads: usize) -> HostTensor {
    let chunk = if x.shape.rank() == 4 {
        x.shape.height() * x.shape.width()
    } else {
        x.shape.channels()
    };
    let mut out = HostTensor::zeros(x.shape.clone());
    for_planes(threads, &mut out.data, chunk, |p, dst| {
        let src = &x.data[p * chunk..][..chunk];
        for (d, &v) in dst.iter_mut().zip(src) {
            *d = v.max(0.0);
        }
    });
    out
}

/// Element-wise residual addition.
pub fn add(a: &HostTensor, b: &HostTensor) -> HostTensor {
    debug_assert_eq!(a.shape, b.shape);
    HostTensor::new(
        a.shape.clone(),
        a.data.iter().zip(&b.data).map(|(x, y)| x + y).collect(),
    )
}

/// Channel-axis concatenation of N rank-4 inputs.
pub fn concat(inputs: &[&HostTensor], out_shape: &Shape) -> HostTensor {
    let n = out_shape.batch();
    let hw = out_shape.height() * out_shape.width();
    let mut out = HostTensor::zeros(out_shape.clone());
    for b in 0..n {
        let mut c_off = 0usize;
        for t in inputs {
            let ct = t.shape.channels();
            let src = &t.data[b * ct * hw..][..ct * hw];
            out.data[(b * out_shape.channels() + c_off) * hw..][..ct * hw]
                .copy_from_slice(src);
            c_off += ct;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(dims: &[usize], data: Vec<f32>) -> HostTensor {
        HostTensor::new(
            Shape::new(dims.to_vec(), crate::graph::DType::F32),
            data,
        )
    }

    #[test]
    fn conv_identity_kernel_passes_input_through() {
        // 1x1 kernel, single in/out channel, weight 1.0: y == x.
        let x = t(&[1, 1, 2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let w = t(&[1, 1, 1, 1], vec![1.0]);
        let win = Window2d::square(1, 1, 0);
        let out = conv2d(&x, &w, None, &win, &x.shape, 1);
        assert_eq!(out.data, x.data);
    }

    #[test]
    fn conv_3x3_hand_computed_with_padding_and_bias() {
        // 3x3 input, 3x3 all-ones kernel, pad 1: each output is the sum
        // of the 3x3 neighbourhood (zeros outside), plus bias 0.5.
        let x = t(&[1, 1, 3, 3], (1..=9).map(|v| v as f32).collect());
        let w = t(&[1, 1, 3, 3], vec![1.0; 9]);
        let b = t(&[1], vec![0.5]);
        let win = Window2d::square(3, 1, 1);
        let out = conv2d(&x, &w, Some(&b), &win, &x.shape, 1);
        // Center = 1+..+9 = 45; corner (0,0) = 1+2+4+5 = 12.
        assert_eq!(out.data[4], 45.0 + 0.5);
        assert_eq!(out.data[0], 12.0 + 0.5);
        assert_eq!(out.data[8], 5.0 + 6.0 + 8.0 + 9.0 + 0.5);
    }

    #[test]
    fn conv_multi_channel_sums_channels() {
        // Two input channels, 1x1 weights (2.0, 3.0): y = 2a + 3b.
        let x = t(&[1, 2, 1, 2], vec![1.0, 2.0, 10.0, 20.0]);
        let w = t(&[1, 2, 1, 1], vec![2.0, 3.0]);
        let win = Window2d::square(1, 1, 0);
        let out = conv2d(&x, &w, None, &win, &Shape::nchw(1, 1, 1, 2), 1);
        assert_eq!(out.data, vec![32.0, 64.0]);
    }

    #[test]
    fn maxpool_hand_computed() {
        let x = t(&[1, 1, 4, 4], (0..16).map(|v| v as f32).collect());
        let win = Window2d::square(2, 2, 0);
        let out = pool2d(
            &x,
            PoolKind::Max,
            &win,
            true,
            &Shape::nchw(1, 1, 2, 2),
            1,
        );
        assert_eq!(out.data, vec![5.0, 7.0, 13.0, 15.0]);
    }

    #[test]
    fn maxpool_padding_is_neg_inf_not_zero() {
        // All-negative input with pad 1: corners must stay negative
        // (zero-padding would wrongly give 0).
        let x = t(&[1, 1, 2, 2], vec![-4.0, -3.0, -2.0, -1.0]);
        let win = Window2d::square(2, 2, 1);
        let out = pool2d(
            &x,
            PoolKind::Max,
            &win,
            true,
            &Shape::nchw(1, 1, 2, 2),
            1,
        );
        assert_eq!(out.data, vec![-4.0, -3.0, -2.0, -1.0]);
    }

    #[test]
    fn avgpool_count_include_pad_divisors() {
        let x = t(&[1, 1, 2, 2], vec![2.0, 2.0, 2.0, 2.0]);
        let win = Window2d::square(2, 1, 1);
        let shape = Shape::nchw(1, 1, 3, 3);
        // include pad: corner window has 1 valid cell, divisor 4.
        let inc = pool2d(&x, PoolKind::Avg, &win, true, &shape, 1);
        assert_eq!(inc.data[0], 2.0 / 4.0);
        assert_eq!(inc.data[4], 2.0); // center: 4 valid cells / 4
        // exclude pad: corner divisor is the 1 valid cell.
        let exc = pool2d(&x, PoolKind::Avg, &win, false, &shape, 1);
        assert_eq!(exc.data[0], 2.0);
        assert_eq!(exc.data[4], 2.0);
    }

    #[test]
    fn adaptive_avg_pool_block_means() {
        let x = t(&[1, 1, 2, 4], vec![1.0, 3.0, 5.0, 7.0, 9.0, 11.0, 13.0, 15.0]);
        let out = adaptive_avg_pool(&x, (1, 2), &Shape::nchw(1, 1, 1, 2), 1);
        // Blocks: {1,3,9,11} and {5,7,13,15}.
        assert_eq!(out.data, vec![6.0, 10.0]);
    }

    #[test]
    fn linear_hand_computed() {
        // x = [1, 2], W = [[1, 2, 3], [4, 5, 6]], b = [1, 2, 3]: every
        // value is integer-exact in f32, so equality is well-defined.
        let x = t(&[1, 2], vec![1.0, 2.0]);
        let w = t(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = t(&[3], vec![1.0, 2.0, 3.0]);
        let out = linear(&x, &w, Some(&b), &Shape::nf(1, 3), 1);
        assert_eq!(out.data, vec![10.0, 14.0, 18.0]);
    }

    #[test]
    fn bn_affine_rank4_and_rank2() {
        let x4 = t(&[1, 2, 1, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let scale = t(&[2], vec![2.0, 10.0]);
        let shift = t(&[2], vec![1.0, -1.0]);
        let out = bn_affine(&x4, &scale, &shift, 1);
        assert_eq!(out.data, vec![3.0, 5.0, 29.0, 39.0]);
        let x2 = t(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let out2 = bn_affine(&x2, &scale, &shift, 1);
        assert_eq!(out2.data, vec![3.0, 19.0, 7.0, 39.0]);
    }

    #[test]
    fn relu_add_concat() {
        let a = t(&[1, 1, 1, 3], vec![-1.0, 0.0, 2.0]);
        assert_eq!(relu(&a, 1).data, vec![0.0, 0.0, 2.0]);
        let b = t(&[1, 1, 1, 3], vec![1.0, 1.0, 1.0]);
        assert_eq!(add(&a, &b).data, vec![0.0, 1.0, 3.0]);
        let c = concat(&[&a, &b], &Shape::nchw(1, 2, 1, 3));
        assert_eq!(c.data, vec![-1.0, 0.0, 2.0, 1.0, 1.0, 1.0]);
    }

    #[test]
    fn threaded_kernels_match_single_threaded() {
        let x = HostTensor::from_seed(
            Shape::nchw(2, 3, 9, 9),
            7,
            crate::rng::ParamKind::Activation,
        );
        let w = HostTensor::from_seed(
            Shape::new(vec![4, 3, 3, 3], crate::graph::DType::F32),
            8,
            crate::rng::ParamKind::Weight,
        );
        let win = Window2d::square(3, 1, 1);
        let out_shape = Shape::nchw(2, 4, 9, 9);
        let a = conv2d(&x, &w, None, &win, &out_shape, 1);
        let b = conv2d(&x, &w, None, &win, &out_shape, 4);
        assert_eq!(a, b);
        let pw = Window2d::square(3, 2, 1);
        let pshape = Shape::nchw(2, 3, 5, 5);
        let p1 = pool2d(&x, PoolKind::Max, &pw, true, &pshape, 1);
        let p4 = pool2d(&x, PoolKind::Max, &pw, true, &pshape, 4);
        assert_eq!(p1, p4);
    }
}
