//! Native CPU backend: real depth-first execution, no artifacts.
//!
//! This subsystem turns the repo's depth-first plans into *measured*
//! wall-clock numbers on the host CPU — the paper's Figure 11/13 claim
//! (up to 41.1% CPU speedup from cache-resident tile processing) made
//! testable without the PJRT artifact toolchain:
//!
//! * [`kernels`] — breadth-first f32 kernels, one per graph layer
//!   (direct conv2d, folded-BN affine, ReLU, max/avg pool, linear,
//!   add, concat). The eager PyTorch-style baseline.
//! * [`walker`] — the depth-first stack walker: one cache-sized band of
//!   one (batch, channel) plane streams through a whole collapsed
//!   sequence via two ping-pong band buffers; pooling arithmetic is
//!   shared with [`kernels`], so both schedules agree bitwise.
//! * [`par`] — `std::thread::scope` work distribution (`--threads N`):
//!   independent bands / planes across workers, per-worker scratch.
//! * [`backend`] — [`CpuBackend`], the `Backend`-trait adapter used by
//!   `Engine::builder().cpu(threads)` and `--backend cpu`.
//!
//! Numeric parity between the two schedules (`allclose`, in practice
//! bit-equality) is asserted by `rust/tests/prop.rs` and by
//! `brainslug run --net <name> --backend cpu`.

pub mod backend;
pub mod kernels;
pub mod par;
pub mod walker;

pub use backend::CpuBackend;
