//! Scoped-thread work distribution for the native CPU backend.
//!
//! The paper's depth-first parallelism (§4.4) assigns *independent work
//! units* — one (batch, channel) plane band on CPU — to parallel
//! executors. Here that is `std::thread::scope`: the item list is split
//! into contiguous groups, one scoped worker per group, each with its
//! own scratch state (the two band buffers of the walker). With
//! `threads <= 1` everything runs inline on the caller's thread, so the
//! single-threaded path has zero spawn overhead.

/// Run `f` over every item, splitting the items across up to `threads`
/// scoped workers. Each worker owns a scratch value built by
/// `mk_scratch` (shared across its items, never across workers).
///
/// Items may hold non-`'static` borrows (e.g. disjoint `&mut [f32]`
/// bands of one output tensor): `std::thread::scope` guarantees every
/// worker joins before this function returns.
pub fn run_items<T, S, F, M>(threads: usize, items: Vec<T>, mk_scratch: M, f: F)
where
    T: Send,
    S: Send,
    F: Fn(T, &mut S) + Sync,
    M: Fn() -> S + Sync,
{
    let n = items.len();
    let workers = threads.max(1).min(n.max(1));
    if workers <= 1 {
        let mut scratch = mk_scratch();
        for item in items {
            f(item, &mut scratch);
        }
        return;
    }
    std::thread::scope(|scope| {
        let f = &f;
        let mk_scratch = &mk_scratch;
        let mut rest = items;
        let mut left = n;
        for w in 0..workers {
            // Balanced contiguous split: remaining / remaining workers.
            let take = left / (workers - w);
            left -= take;
            let group: Vec<T> = rest.drain(..take).collect();
            scope.spawn(move || {
                let mut scratch = mk_scratch();
                for item in group {
                    f(item, &mut scratch);
                }
            });
        }
    });
}

/// Convenience: apply `f(plane_index, plane)` to every `plane_len` chunk
/// of `data`, across up to `threads` workers. The breadth-first kernels
/// use this to parallelize over (batch, channel) — or (batch,
/// out_channel) for convolution — planes.
pub fn for_planes<F>(threads: usize, data: &mut [f32], plane_len: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    debug_assert!(plane_len > 0 && data.len() % plane_len == 0);
    let items: Vec<(usize, &mut [f32])> = data.chunks_mut(plane_len).enumerate().collect();
    run_items(threads, items, || (), |(i, plane), _scratch| f(i, plane));
}

/// Declarative concurrency topology of the band-worker pool for the
/// static lint. Trivially safe by construction — scoped workers with no
/// channels, joined implicitly at scope end — but declared anyway so
/// the lint inventory covers every place the runtime spawns threads.
pub fn topology(threads: usize) -> crate::analysis::Topology {
    use crate::analysis::{ExitCondition, Topology};
    Topology::new("cpu-band-pool").thread("band-worker", threads, ExitCondition::ScopeEnd)
}

/// Model-checked replica of the band-pool protocol for the schedule
/// checker (`brainslug check --schedules`).
///
/// [`run_items`] itself uses `std::thread::scope` so workers can borrow
/// non-`'static` band slices — scoped spawns cannot be routed through
/// the model (its threads must be `'static`), so the replica models the
/// same shape with owned state: a contiguous split of `items` work
/// units, one obligation per item, per-worker scratch accumulation
/// merged under a shared results mutex, and an explicit join standing
/// in for the scope end. What this checks: the split covers every item
/// exactly once under every schedule (quiescence, BSL056), the merge
/// lock is cycle-free (BSL051), and the pool always joins (BSL050).
pub fn pool_protocol(threads: usize, items: usize) {
    use crate::conc::sync::{model, Mutex};
    use std::sync::Arc;

    let results = Arc::new(Mutex::labeled(Vec::<usize>::new(), "band-results"));
    let workers = threads.max(1).min(items.max(1));
    let mut handles = Vec::with_capacity(workers);
    let mut next = 0usize;
    let mut left = items;
    for w in 0..workers {
        // Balanced contiguous split, mirroring `run_items`.
        let take = left / (workers - w);
        left -= take;
        let group: Vec<usize> = (next..next + take).collect();
        next += take;
        let results = results.clone();
        handles.push(model::spawn(&format!("band-worker-{w}"), move || {
            // Per-worker scratch with per-item obligations…
            let mut scratch = Vec::with_capacity(group.len());
            for item in group {
                scratch.push((item, model::obligation(&format!("band-{item}"))));
            }
            // …merged once under the shared lock, like a result gather.
            let mut merged = results.lock().unwrap_or_else(|p| p.into_inner());
            for (item, ob) in scratch {
                merged.push(item);
                ob.complete();
            }
        }));
    }
    for h in handles {
        h.join();
    }
    let merged = results.lock().unwrap_or_else(|p| p.into_inner());
    assert_eq!(merged.len(), items, "band pool lost or duplicated items");
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn all_items_processed_once_any_thread_count() {
        for threads in [1, 2, 3, 8, 100] {
            let counter = AtomicUsize::new(0);
            let items: Vec<usize> = (0..37).collect();
            run_items(
                threads,
                items,
                || (),
                |i, _scratch| {
                    counter.fetch_add(i + 1, Ordering::Relaxed);
                },
            );
            // sum of 1..=37
            assert_eq!(counter.load(Ordering::Relaxed), 37 * 38 / 2, "{threads}");
        }
    }

    #[test]
    fn for_planes_writes_disjoint_chunks() {
        for threads in [1, 3] {
            let mut data = vec![0.0f32; 24];
            for_planes(threads, &mut data, 4, |i, plane| {
                for v in plane.iter_mut() {
                    *v = i as f32;
                }
            });
            for (i, chunk) in data.chunks(4).enumerate() {
                assert!(chunk.iter().all(|&v| v == i as f32));
            }
        }
    }

    #[test]
    fn scratch_is_per_worker() {
        // Scratch accumulates within a worker; the total across workers
        // must still cover every item exactly once.
        let total = AtomicUsize::new(0);
        run_items(
            4,
            (0..100).collect::<Vec<usize>>(),
            Vec::new,
            |i, seen: &mut Vec<usize>| {
                seen.push(i);
                total.fetch_add(1, Ordering::Relaxed);
            },
        );
        assert_eq!(total.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn empty_items_is_a_noop() {
        run_items(4, Vec::<usize>::new(), || (), |_, _: &mut ()| {
            panic!("no items")
        });
    }
}
