//! Deterministic parameter store.
//!
//! Network parameters are generated (not trained) from the shared
//! SplitMix64 stream keyed by `(network seed, node name, param kind)` —
//! identically in `python/compile/detrng.py` — so the rust scheduler and
//! the python oracle compute over the same weights. Inference batch-norm
//! is folded here into per-channel `scale`/`shift` exactly as the python
//! side folds it.

use std::collections::HashMap;
use std::sync::Arc;

use crate::graph::{node_param_tags, Graph, Layer, NodeId, Shape};
use crate::rng::{fill_param, tensor_seed, ParamKind};

use super::tensor::HostTensor;

/// Lazily generated, cached parameters for one graph instance.
pub struct ParamStore {
    graph: Arc<Graph>,
    seed: u64,
    cache: HashMap<(NodeId, &'static str), HostTensor>,
    /// Folded batch-norm (scale, shift) per node: computed once, so
    /// repeated stack executions (`run_stack` gathers every bn op's
    /// folded pair on every invocation) stop re-folding — see the
    /// bn-gather microbench in `benches/optimizer_hotpath.rs`.
    bn_cache: HashMap<NodeId, (HostTensor, HostTensor)>,
}

fn kind_of(tag_kind: &str) -> ParamKind {
    match tag_kind {
        "weight" => ParamKind::Weight,
        "bias" => ParamKind::Bias,
        "bn_gamma" => ParamKind::BnGamma,
        "bn_beta" => ParamKind::BnBeta,
        "bn_mean" => ParamKind::BnMean,
        "bn_var" => ParamKind::BnVar,
        other => panic!("unknown param kind {other}"),
    }
}

impl ParamStore {
    pub fn new(graph: Arc<Graph>, seed: u64) -> Self {
        ParamStore {
            graph,
            seed,
            cache: HashMap::new(),
            bn_cache: HashMap::new(),
        }
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Raw parameter tensor of `node` by kind name (e.g. "weight").
    pub fn raw(&mut self, node: NodeId, want: &'static str) -> HostTensor {
        if let Some(t) = self.cache.get(&(node, want)) {
            return t.clone();
        }
        let n = self.graph.node(node);
        let tags = node_param_tags(&self.graph, n);
        let (tag, kind, shape) = tags
            .into_iter()
            .find(|(_, k, _)| *k == want)
            .unwrap_or_else(|| panic!("node {} has no param '{want}'", n.name));
        let s = tensor_seed(self.seed, &tag);
        let t = HostTensor::new(shape.clone(), fill_param(s, shape.numel(), kind_of(kind)));
        self.cache.insert((node, want), t.clone());
        t
    }

    /// Folded batch-norm (scale, shift):
    /// `scale = gamma / sqrt(var + eps)`, `shift = beta - mean * scale`.
    /// Cached per node after the first fold.
    pub fn bn_folded(&mut self, node: NodeId) -> (HostTensor, HostTensor) {
        if let Some(pair) = self.bn_cache.get(&node) {
            return pair.clone();
        }
        let eps = match &self.graph.node(node).layer {
            Layer::BatchNorm2d { eps } => *eps,
            other => panic!("bn_folded on {other:?}"),
        };
        let gamma = self.raw(node, "bn_gamma");
        let beta = self.raw(node, "bn_beta");
        let mean = self.raw(node, "bn_mean");
        let var = self.raw(node, "bn_var");
        let c = gamma.data.len();
        let mut scale = Vec::with_capacity(c);
        let mut shift = Vec::with_capacity(c);
        for i in 0..c {
            let s = gamma.data[i] / (var.data[i] + eps).sqrt();
            scale.push(s);
            shift.push(beta.data[i] - mean.data[i] * s);
        }
        let shape = Shape::new(vec![c], gamma.shape.dtype);
        let pair = (
            HostTensor::new(shape.clone(), scale),
            HostTensor::new(shape, shift),
        );
        self.bn_cache.insert(node, pair.clone());
        pair
    }

    /// Runtime inputs for a layer executable, in artifact argument order:
    /// conv/linear → [weight, (bias)]; bn → [scale, shift]; others → [].
    pub fn exec_params(&mut self, node: NodeId) -> Vec<HostTensor> {
        // Clone the (small) layer descriptor first: matching on a borrow
        // of `self.graph` would conflict with the `&mut self` raw/
        // bn_folded calls below now that the store owns its graph.
        let layer = self.graph.node(node).layer.clone();
        match layer {
            Layer::Conv2d { bias, .. } | Layer::Linear { bias, .. } => {
                let mut v = vec![self.raw(node, "weight")];
                if bias {
                    v.push(self.raw(node, "bias"));
                }
                v
            }
            Layer::BatchNorm2d { .. } => {
                let (s, b) = self.bn_folded(node);
                vec![s, b]
            }
            _ => vec![],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Window2d;

    fn bn_graph() -> Arc<Graph> {
        let mut g = Graph::new("t", Shape::nchw(1, 4, 8, 8));
        g.push(
            "conv",
            Layer::Conv2d {
                out_channels: 4,
                window: Window2d::square(3, 1, 1),
                bias: true,
            },
        );
        g.push("bn", Layer::BatchNorm2d { eps: 1e-5 });
        Arc::new(g)
    }

    #[test]
    fn deterministic_and_cached() {
        let g = bn_graph();
        let mut p1 = ParamStore::new(g.clone(), 99);
        let mut p2 = ParamStore::new(g.clone(), 99);
        assert_eq!(p1.raw(1, "weight"), p2.raw(1, "weight"));
        let mut p3 = ParamStore::new(g, 100);
        assert_ne!(p1.raw(1, "weight").data, p3.raw(1, "weight").data);
    }

    #[test]
    fn bn_folding_math() {
        let g = bn_graph();
        let mut p = ParamStore::new(g, 7);
        let gamma = p.raw(2, "bn_gamma");
        let beta = p.raw(2, "bn_beta");
        let mean = p.raw(2, "bn_mean");
        let var = p.raw(2, "bn_var");
        let (scale, shift) = p.bn_folded(2);
        for i in 0..4 {
            let s = gamma.data[i] / (var.data[i] + 1e-5).sqrt();
            assert!((scale.data[i] - s).abs() < 1e-7);
            assert!((shift.data[i] - (beta.data[i] - mean.data[i] * s)).abs() < 1e-7);
        }
    }

    #[test]
    fn bn_fold_is_cached_and_stable() {
        let g = bn_graph();
        let mut p = ParamStore::new(g, 7);
        let first = p.bn_folded(2);
        // Second call hits the fold cache and must be identical.
        let second = p.bn_folded(2);
        assert_eq!(first, second);
    }

    #[test]
    fn exec_params_order() {
        let g = bn_graph();
        let mut p = ParamStore::new(g, 7);
        let conv = p.exec_params(1);
        assert_eq!(conv.len(), 2); // weight, bias
        assert_eq!(conv[0].shape.dims, vec![4, 4, 3, 3]);
        assert_eq!(conv[1].shape.dims, vec![4]);
        let bn = p.exec_params(2);
        assert_eq!(bn.len(), 2); // scale, shift
        let relu_params = {
            let mut g2 = (*bn_graph()).clone();
            g2.push("relu", Layer::Relu);
            let mut p2 = ParamStore::new(Arc::new(g2), 7);
            p2.exec_params(3)
        };
        assert!(relu_params.is_empty());
    }
}
