//! Runtime: PJRT client wrapper, artifact manifest, host tensors,
//! deterministic parameters, and the compile-request bridge to the
//! python AOT path. Python never runs here — the scheduler executes
//! pre-compiled HLO artifacts only.

pub mod client;
pub mod naming;
pub mod params;
pub mod requests;
pub mod tensor;

pub use client::{ArtifactSpec, Manifest, Runtime};
pub use naming::{layer_exec_name, stack_exec_name};
pub use params::ParamStore;
pub use requests::RequestSet;
pub use tensor::HostTensor;
