//! Compile-request emission: the bridge from the rust optimizer to the
//! python AOT path.
//!
//! `brainslug emit-requests` runs the optimizer over the experiment set
//! and serializes every *distinct* executable the scheduler will need —
//! per-layer executables (the breadth-first baseline and un-stacked plan
//! segments) and fused per-stack executables (the depth-first kernels) —
//! into `artifacts/requests.json`. `python/compile/aot.py` lowers each
//! request to an HLO-text artifact and writes `artifacts/manifest.json`.
//! Python never decides *what* to compile; the optimizer does (the
//! paper's Code Generator, §4.1 step 5).

use std::collections::BTreeMap;

use crate::graph::{graph_to_json, Graph, Node};
use crate::json::Json;
use crate::optimizer::{OpKind, Plan, Segment, Stack};

use super::naming::{layer_exec_name, stack_exec_name};

/// Accumulates deduplicated compile requests across experiments.
#[derive(Debug, Default)]
pub struct RequestSet {
    layers: BTreeMap<String, Json>,
    stacks: BTreeMap<String, Json>,
    oracles: BTreeMap<String, Json>,
}

fn shape_json(s: &crate::graph::Shape) -> Json {
    let mut o = Json::object();
    o.set(
        "dims",
        Json::Arr(s.dims.iter().map(|&d| Json::from_usize(d)).collect()),
    );
    o.set("dtype", Json::Str(s.dtype.name().to_string()));
    o
}

fn op_json(kind: &OpKind) -> Json {
    let mut o = Json::object();
    match kind {
        OpKind::BnAffine { eps } => {
            o.set("op", Json::Str("bn".into()));
            o.set("eps", Json::Num(*eps as f64));
        }
        OpKind::Relu => {
            o.set("op", Json::Str("relu".into()));
        }
        OpKind::Identity => {
            o.set("op", Json::Str("id".into()));
        }
        OpKind::Pool {
            kind,
            window,
            ceil_mode,
            count_include_pad,
        } => {
            o.set("op", Json::Str("pool".into()));
            o.set(
                "pool",
                Json::Str(
                    match kind {
                        crate::graph::PoolKind::Max => "max",
                        crate::graph::PoolKind::Avg => "avg",
                    }
                    .into(),
                ),
            );
            o.set(
                "kernel",
                Json::Arr(vec![
                    Json::from_usize(window.kernel.0),
                    Json::from_usize(window.kernel.1),
                ]),
            );
            o.set(
                "stride",
                Json::Arr(vec![
                    Json::from_usize(window.stride.0),
                    Json::from_usize(window.stride.1),
                ]),
            );
            o.set(
                "pad",
                Json::Arr(vec![
                    Json::from_usize(window.pad.0),
                    Json::from_usize(window.pad.1),
                ]),
            );
            o.set("ceil_mode", Json::Bool(*ceil_mode));
            o.set("count_include_pad", Json::Bool(*count_include_pad));
        }
    }
    o
}

fn stack_json(stack: &Stack) -> Json {
    let mut o = Json::object();
    o.set("name", Json::Str(stack_exec_name(stack)));
    o.set("signature", Json::Str(stack.signature.clone()));
    o.set("in_shape", shape_json(stack.in_shape()));
    o.set("out_shape", shape_json(stack.out_shape()));
    let seqs: Vec<Json> = stack
        .sequences
        .iter()
        .map(|seq| {
            let mut sj = Json::object();
            sj.set("tile_rows", Json::from_usize(seq.tile_rows));
            sj.set("in_shape", shape_json(seq.in_shape()));
            sj.set("out_shape", shape_json(seq.out_shape()));
            let steps: Vec<Json> = seq
                .steps
                .iter()
                .map(|step| Json::Arr(step.ops.iter().map(|op| op_json(&op.kind)).collect()))
                .collect();
            sj.set("steps", Json::Arr(steps));
            sj
        })
        .collect();
    o.set("sequences", Json::Arr(seqs));
    o
}

impl RequestSet {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register the executable for one non-stacked layer (deduplicated
    /// by signature; scheduler-native layers register nothing).
    fn register_layer(&mut self, graph: &Graph, node: &Node) {
        if let Some(name) = layer_exec_name(graph, node) {
            self.layers.entry(name.clone()).or_insert_with(|| {
                let mut o = Json::object();
                o.set("name", Json::Str(name));
                let in_shapes: Vec<Json> = node
                    .inputs
                    .iter()
                    .map(|&i| shape_json(&graph.node(i).shape))
                    .collect();
                o.set("in_shapes", Json::Arr(in_shapes));
                o.set("out_shape", shape_json(&node.shape));
                crate::graph::json::layer_fields_into(&mut o, &node.layer);
                o
            });
        }
    }

    /// Register everything one plan segment needs. Branch segments
    /// recurse into their arms and register the join as a plain layer
    /// executable (the PJRT path dispatches it; only the sim model
    /// fuses its cost into the branch schedule).
    fn register_segment(&mut self, graph: &Graph, seg: &Segment) {
        match seg {
            Segment::Single(id) => self.register_layer(graph, graph.node(*id)),
            Segment::Stack(st) => {
                self.stacks
                    .entry(stack_exec_name(st))
                    .or_insert_with(|| stack_json(st));
            }
            Segment::Branch { arms, join } => {
                for arm in arms {
                    for seg in arm {
                        self.register_segment(graph, seg);
                    }
                }
                self.register_layer(graph, graph.node(*join));
            }
        }
    }

    /// Register every executable a breadth-first (baseline) run of
    /// `graph` needs: one per distinct layer signature.
    pub fn add_baseline(&mut self, graph: &Graph) {
        for node in graph.nodes.iter().skip(1) {
            self.register_layer(graph, node);
        }
    }

    /// Register the executables a BrainSlug plan needs: fused stacks
    /// (chain-level and inside branch arms) plus the single layers it
    /// leaves untouched.
    pub fn add_plan(&mut self, graph: &Graph, plan: &Plan) {
        for seg in &plan.segments {
            self.register_segment(graph, seg);
        }
    }

    /// Register a numerics-oracle request: python will run `graph` with
    /// detrng parameters (seed) on a detrng input and dump input/output
    /// tensors for the rust integration tests.
    pub fn add_oracle(&mut self, tag: &str, graph: &Graph, seed: u64) {
        let mut o = Json::object();
        o.set("tag", Json::Str(tag.to_string()));
        o.set("seed", Json::from_usize(seed as usize));
        o.set("graph", graph_to_json(graph));
        self.oracles.insert(tag.to_string(), o);
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn num_stacks(&self) -> usize {
        self.stacks.len()
    }

    /// Serialize the full request set.
    pub fn to_json(&self) -> Json {
        let mut root = Json::object();
        root.set(
            "layers",
            Json::Arr(self.layers.values().cloned().collect()),
        );
        root.set(
            "stacks",
            Json::Arr(self.stacks.values().cloned().collect()),
        );
        root.set(
            "oracles",
            Json::Arr(self.oracles.values().cloned().collect()),
        );
        root
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceSpec;
    use crate::optimizer::{optimize, CollapseOptions};
    use crate::zoo;

    #[test]
    fn dedup_across_networks() {
        let mut rs = RequestSet::new();
        let g16 = zoo::build("vgg16", zoo::small_config("vgg16", 2));
        let g19 = zoo::build("vgg19", zoo::small_config("vgg19", 2));
        rs.add_baseline(&g16);
        let after_16 = rs.num_layers();
        rs.add_baseline(&g19);
        // VGG-19 shares nearly all layer signatures with VGG-16.
        assert!(rs.num_layers() < after_16 + 6);
    }

    #[test]
    fn plan_requests_contain_stacks() {
        let mut rs = RequestSet::new();
        let g = zoo::build("vgg11_bn", zoo::small_config("vgg11_bn", 2));
        let plan = optimize(&g, &DeviceSpec::tpu_core(), &CollapseOptions::default());
        rs.add_plan(&g, &plan);
        assert!(rs.num_stacks() >= 1);
        let j = rs.to_json();
        let stacks = j.arr_field("stacks").unwrap();
        let s0 = &stacks[0];
        assert!(s0.str_field("name").unwrap().starts_with("stack_"));
        assert!(!s0.arr_field("sequences").unwrap().is_empty());
    }

    #[test]
    fn branchy_plan_registers_arm_stacks_and_join() {
        let mut rs = RequestSet::new();
        let g = zoo::build("resnet18", zoo::small_config("resnet18", 1));
        let plan = optimize(&g, &DeviceSpec::tpu_core(), &CollapseOptions::default());
        assert!(plan.num_branches() > 0);
        rs.add_plan(&g, &plan);
        assert!(rs.num_stacks() >= 1);
        // The residual joins register as plain add executables so the
        // PJRT scheduler can dispatch them.
        assert!(rs.layers.keys().any(|k| k.starts_with("add_in")));
    }

    #[test]
    fn request_json_roundtrips_through_parser() {
        let mut rs = RequestSet::new();
        let g = zoo::build("alexnet", zoo::small_config("alexnet", 1));
        rs.add_baseline(&g);
        rs.add_oracle("alexnet_small_b1", &g, 42);
        let text = rs.to_json().to_string_pretty();
        let parsed = crate::json::parse(&text).unwrap();
        assert_eq!(parsed.arr_field("oracles").unwrap().len(), 1);
        assert!(parsed.arr_field("layers").unwrap().len() > 5);
    }
}
