//! Canonical executable names shared between the request emitter, the
//! scheduler, and `python/compile/aot.py`. One name = one AOT-compiled
//! HLO artifact; identical layers/stacks across networks share artifacts
//! (the paper's "only generates the code once" dedup, §4.3).

use crate::graph::{Graph, Layer, Node, PoolKind};
use crate::optimizer::Stack;

/// Executable name for a non-stacked layer. Layers with no runtime
/// compute (input, dropout, flatten) return `None` — the scheduler
/// handles them natively.
pub fn layer_exec_name(graph: &Graph, node: &Node) -> Option<String> {
    let in_sig = |i: usize| graph.node(node.inputs[i]).shape.sig();
    Some(match &node.layer {
        Layer::Input { .. } | Layer::Dropout { .. } | Layer::Flatten => return None,
        Layer::Conv2d {
            out_channels,
            window,
            bias,
        } => format!(
            "conv2d_oc{}_{}{}_in{}",
            out_channels,
            window.sig(),
            if *bias { "_bias" } else { "" },
            in_sig(0)
        ),
        Layer::Linear { out_features, bias } => format!(
            "linear_of{}{}_in{}",
            out_features,
            if *bias { "_bias" } else { "" },
            in_sig(0)
        ),
        Layer::Pool2d {
            kind,
            window,
            ceil_mode,
            count_include_pad,
        } => {
            let k = match kind {
                PoolKind::Max => "max",
                PoolKind::Avg => "avg",
            };
            let mut s = format!("{}pool_{}", k, window.sig());
            if *ceil_mode {
                s.push_str("_ceil");
            }
            if matches!(kind, PoolKind::Avg) && !*count_include_pad {
                s.push_str("_nip");
            }
            format!("{}_in{}", s, in_sig(0))
        }
        Layer::AdaptiveAvgPool { out_hw } => {
            format!("gap_{}x{}_in{}", out_hw.0, out_hw.1, in_sig(0))
        }
        Layer::BatchNorm2d { .. } => format!("bn_in{}", in_sig(0)),
        Layer::Relu => format!("relu_in{}", in_sig(0)),
        Layer::Add => format!("add_in{}", in_sig(0)),
        Layer::Concat => {
            let sigs: Vec<String> = (0..node.inputs.len()).map(in_sig).collect();
            format!("concat_in{}", sigs.join("+"))
        }
    })
}

/// Executable name for a collapsed stack.
pub fn stack_exec_name(stack: &Stack) -> String {
    stack.artifact_name()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Graph, Shape, Window2d};

    #[test]
    fn names_are_shape_qualified() {
        let mut g = Graph::new("t", Shape::nchw(2, 3, 8, 8));
        let c = g.push(
            "conv",
            Layer::Conv2d {
                out_channels: 4,
                window: Window2d::square(3, 1, 1),
                bias: true,
            },
        );
        assert_eq!(
            layer_exec_name(&g, g.node(c)).unwrap(),
            "conv2d_oc4_k3x3s1x1p1x1_bias_in2x3x8x8f32"
        );
        let r = g.push("relu", Layer::Relu);
        assert_eq!(
            layer_exec_name(&g, g.node(r)).unwrap(),
            "relu_in2x4x8x8f32"
        );
        let f = g.push("flatten", Layer::Flatten);
        assert!(layer_exec_name(&g, g.node(f)).is_none());
        let l = g.push(
            "fc",
            Layer::Linear {
                out_features: 10,
                bias: false,
            },
        );
        assert_eq!(
            layer_exec_name(&g, g.node(l)).unwrap(),
            "linear_of10_in2x256f32"
        );
    }

    #[test]
    fn concat_name_lists_all_inputs() {
        let mut g = Graph::new("t", Shape::nchw(1, 2, 4, 4));
        let a = g.push("r1", Layer::Relu);
        let b = g.add("r2", Layer::Relu, &[0]);
        let c = g.add("cat", Layer::Concat, &[a, b]);
        assert_eq!(
            layer_exec_name(&g, g.node(c)).unwrap(),
            "concat_in1x2x4x4f32+1x2x4x4f32"
        );
    }
}
