//! PJRT runtime: load AOT-compiled HLO-text artifacts, compile them on
//! the CPU PJRT client once, cache the executables, and execute them with
//! host tensors.
//!
//! Interchange is HLO *text* (not serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids the bundled xla_extension 0.5.1 rejects, while
//! the text parser reassigns ids — see DESIGN.md and
//! `/opt/xla-example/README.md`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use crate::graph::{DType, Shape};
use crate::json::{parse, Json};

use super::tensor::HostTensor;

/// One artifact's manifest entry.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    /// Path of the HLO text file, relative to the artifact dir.
    pub path: String,
    pub input_shapes: Vec<Shape>,
    pub output_shape: Shape,
}

fn shape_from_json(j: &Json) -> Result<Shape> {
    let dims = j.req("dims")?.usize_vec()?;
    let dtype = match j.str_field("dtype")?.as_str() {
        "f32" => DType::F32,
        "bf16" => DType::BF16,
        other => bail!("unknown dtype {other}"),
    };
    Ok(Shape::new(dims, dtype))
}

/// The parsed `artifacts/manifest.json`.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: HashMap<String, ArtifactSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let j = parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        let mut entries = HashMap::new();
        for e in j.arr_field("executables")? {
            let name = e.str_field("name")?;
            let spec = ArtifactSpec {
                name: name.clone(),
                path: e.str_field("path")?,
                input_shapes: e
                    .arr_field("inputs")?
                    .iter()
                    .map(shape_from_json)
                    .collect::<Result<_>>()?,
                output_shape: shape_from_json(e.req("output")?)?,
            };
            entries.insert(name, spec);
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            entries,
        })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest (run `make artifacts`)"))
    }
}

/// PJRT client + compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    executables: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    /// Cumulative executable-compile time (perf accounting).
    pub compile_seconds: Mutex<f64>,
}

impl Runtime {
    /// Create a CPU PJRT runtime over an artifact directory.
    pub fn new(artifact_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            manifest,
            executables: Mutex::new(HashMap::new()),
            compile_seconds: Mutex::new(0.0),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Number of loaded (compiled) executables.
    pub fn loaded_count(&self) -> usize {
        // Poison-safe: a panicked compile thread must not wedge stats.
        self.executables
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .len()
    }

    /// Get (compiling and caching on first use) an executable by name.
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self
            .executables
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .get(name)
        {
            return Ok(exe.clone());
        }
        let spec = self.manifest.get(name)?;
        let path = self.manifest.dir.join(&spec.path);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        *self
            .compile_seconds
            .lock()
            .unwrap_or_else(|p| p.into_inner()) += t0.elapsed().as_secs_f64();
        let exe = std::sync::Arc::new(exe);
        self.executables
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Eagerly compile every manifest entry (serving warm-up).
    pub fn preload_all(&self) -> Result<usize> {
        let names: Vec<String> = self.manifest.entries.keys().cloned().collect();
        for n in &names {
            self.load(n)?;
        }
        Ok(names.len())
    }

    /// Execute artifact `name` on `inputs`; returns the single output.
    ///
    /// Shapes are validated against the manifest before dispatch so a
    /// mismatched call fails with a readable error instead of an XLA
    /// abort.
    pub fn execute(&self, name: &str, inputs: &[&HostTensor]) -> Result<HostTensor> {
        let spec = self.manifest.get(name)?.clone();
        if inputs.len() != spec.input_shapes.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                spec.input_shapes.len(),
                inputs.len()
            );
        }
        for (i, (t, s)) in inputs.iter().zip(&spec.input_shapes).enumerate() {
            if &t.shape != s {
                bail!("{name}: input {i} shape {} != expected {}", t.shape, s);
            }
        }
        let exe = self.load(name)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let bytes: Vec<u8> = t.data.iter().flat_map(|v| v.to_le_bytes()).collect();
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::F32,
                    &t.shape.dims,
                    &bytes,
                )
                .map_err(|e| anyhow!("literal for {name}: {e:?}"))
            })
            .collect::<Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result of {name}: {e:?}"))?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = lit
            .to_tuple1()
            .map_err(|e| anyhow!("untuple result of {name}: {e:?}"))?;
        let data = out
            .to_vec::<f32>()
            .map_err(|e| anyhow!("read result of {name}: {e:?}"))?;
        if data.len() != spec.output_shape.numel() {
            bail!(
                "{name}: output has {} elements, manifest says {}",
                data.len(),
                spec.output_shape.numel()
            );
        }
        Ok(HostTensor::new(spec.output_shape.clone(), data))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_missing_dir_errors() {
        let err = Manifest::load(Path::new("/nonexistent/dir")).unwrap_err();
        assert!(err.to_string().contains("manifest.json"));
    }

    #[test]
    fn manifest_parses_entries() {
        let dir = std::env::temp_dir().join("bs_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"executables":[{"name":"relu_in1x2f32","path":"relu.hlo.txt",
                "inputs":[{"dims":[1,2],"dtype":"f32"}],
                "output":{"dims":[1,2],"dtype":"f32"}}]}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        let spec = m.get("relu_in1x2f32").unwrap();
        assert_eq!(spec.input_shapes.len(), 1);
        assert_eq!(spec.output_shape.dims, vec![1, 2]);
        assert!(m.get("nope").is_err());
    }
}
