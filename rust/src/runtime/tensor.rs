//! Host-side f32 tensors: the scheduler's activation/parameter values.

use std::io::{Read, Write};
use std::path::Path;

use crate::graph::Shape;

/// A dense row-major f32 tensor on the host.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Shape,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(shape: Shape, data: Vec<f32>) -> Self {
        assert_eq!(shape.numel(), data.len(), "shape/data mismatch");
        HostTensor { shape, data }
    }

    pub fn zeros(shape: Shape) -> Self {
        let n = shape.numel();
        HostTensor {
            shape,
            data: vec![0.0; n],
        }
    }

    /// Deterministic fill from the shared SplitMix64 stream.
    pub fn from_seed(shape: Shape, seed: u64, kind: crate::rng::ParamKind) -> Self {
        let n = shape.numel();
        HostTensor {
            shape,
            data: crate::rng::fill_param(seed, n, kind),
        }
    }

    /// Metadata-only reshape (same element count).
    pub fn reshape(mut self, shape: Shape) -> Self {
        assert_eq!(shape.numel(), self.data.len(), "reshape numel mismatch");
        self.shape = shape;
        self
    }

    /// Max absolute difference against another tensor.
    pub fn max_abs_diff(&self, other: &HostTensor) -> f32 {
        assert_eq!(self.shape, other.shape, "shape mismatch in compare");
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// allclose with combined absolute/relative tolerance.
    pub fn allclose(&self, other: &HostTensor, atol: f32, rtol: f32) -> bool {
        if self.shape != other.shape {
            return false;
        }
        self.data
            .iter()
            .zip(&other.data)
            .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }

    /// Write as raw little-endian f32 (shape carried externally).
    pub fn write_f32_file(&self, path: &Path) -> anyhow::Result<()> {
        let mut f = std::fs::File::create(path)?;
        let bytes: Vec<u8> = self.data.iter().flat_map(|v| v.to_le_bytes()).collect();
        f.write_all(&bytes)?;
        Ok(())
    }

    /// Read raw little-endian f32 with a known shape.
    pub fn read_f32_file(path: &Path, shape: Shape) -> anyhow::Result<Self> {
        let mut f = std::fs::File::open(path)
            .map_err(|e| anyhow::anyhow!("open {}: {e}", path.display()))?;
        let mut bytes = Vec::new();
        f.read_to_end(&mut bytes)?;
        if bytes.len() != shape.numel() * 4 {
            anyhow::bail!(
                "{}: {} bytes but shape {} needs {}",
                path.display(),
                bytes.len(),
                shape,
                shape.numel() * 4
            );
        }
        let data = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(HostTensor { shape, data })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::ParamKind;

    #[test]
    fn roundtrip_file() {
        let t = HostTensor::from_seed(Shape::nchw(2, 3, 4, 5), 7, ParamKind::Activation);
        let dir = std::env::temp_dir().join("bs_tensor_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.f32");
        t.write_f32_file(&p).unwrap();
        let back = HostTensor::read_f32_file(&p, t.shape.clone()).unwrap();
        assert_eq!(t, back);
        // Wrong shape errors.
        assert!(HostTensor::read_f32_file(&p, Shape::nf(1, 3)).is_err());
    }

    #[test]
    fn allclose_tolerances() {
        let a = HostTensor::new(Shape::nf(1, 3), vec![1.0, 2.0, 3.0]);
        let mut b = a.clone();
        b.data[1] += 1e-6;
        assert!(a.allclose(&b, 1e-5, 0.0));
        assert!(!a.allclose(&b, 1e-8, 0.0));
        assert!(a.allclose(&b, 0.0, 1e-5));
        // f32 rounding: 2.0 + 1e-6 lands on the nearest representable.
        assert!((a.max_abs_diff(&b) - 1e-6).abs() < 1e-7);
    }

    #[test]
    fn reshape_checks_numel() {
        let t = HostTensor::zeros(Shape::nchw(1, 2, 3, 4));
        let r = t.reshape(Shape::nf(1, 24));
        assert_eq!(r.shape, Shape::nf(1, 24));
    }

    #[test]
    #[should_panic]
    fn reshape_bad_numel_panics() {
        HostTensor::zeros(Shape::nchw(1, 2, 3, 4)).reshape(Shape::nf(1, 25));
    }
}
