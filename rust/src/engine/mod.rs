//! The `Engine` facade: one entry point for the whole
//! graph → plan → execute pipeline.
//!
//! The paper's pitch is *transparency* — acceleration with "only tiny
//! adjustments to the software" (§1) — so the public API should be one
//! call, not seven. Before this module, every entry point hand-wired
//! `zoo::try_build` → `DeviceSpec` → `optimize` → `plan.validate` →
//! `Runtime::new` → `Executor::new` → `run_plan`. Now:
//!
//! ```no_run
//! # fn main() -> anyhow::Result<()> {
//! use brainslug::engine::Engine;
//!
//! let mut engine = Engine::builder()
//!     .zoo_small("vgg11_bn", 8)      // or .graph(my_graph)
//!     .sim()                         // or .artifacts("artifacts")
//!     .build()?;
//! let input = engine.synthetic_input();
//! let (output, stats) = engine.run(input)?;
//! # Ok(()) }
//! ```
//!
//! [`EngineBuilder`] owns the full lifecycle: network resolution (zoo
//! name or [`Graph`]), device selection, optimization mode
//! ([`Mode::Baseline`] | [`Mode::BrainSlug`]), plan validation, and
//! backend construction. [`Backend`] is the execution seam: the
//! [`PjrtBackend`] runs AOT artifacts for real, the [`SimBackend`]
//! drives the `memsim` perf model with no artifacts at all, and the
//! [`CpuBackend`] computes everything in-process with native f32
//! kernels (breadth-first baseline vs. depth-first band walker, see
//! [`crate::cpu`]). The builder
//! is `Send` (the engine itself is not — PJRT internals are `Rc`-based),
//! so servers ship the builder across threads and build in place.

mod backend;

pub use backend::{Backend, PjrtBackend, SimBackend, Workload};
pub use crate::cpu::CpuBackend;

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::analysis;
use crate::autotune::{self, ProfileStore, TuneLevel};
use crate::device::DeviceSpec;
use crate::graph::Graph;
use crate::memsim::{simulate_baseline, simulate_plan, BaselineSim, PlanSim};
use crate::optimizer::{optimize, CollapseOptions, Plan};
use crate::runtime::HostTensor;
use crate::scheduler::ExecStats;
use crate::zoo::{self, ZooConfig};

/// Seed for deterministic parameters/inputs when none is given —
/// the same stream the python AOT oracle uses.
pub const DEFAULT_SEED: u64 = 0x5EED_2026;

/// Default AOT artifact directory (relative to the repo root / cwd).
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";

/// Where the network comes from.
#[derive(Debug, Clone)]
enum NetworkSource {
    /// A model-zoo architecture by name (family aliases like "vgg"
    /// resolve via [`zoo::resolve`]).
    Zoo { name: String, config: ZooConfig },
    /// A caller-built graph.
    Graph(Arc<Graph>),
}

/// Optimization mode: run the network as-is, or collapse it depth-first.
#[derive(Debug, Clone)]
pub enum Mode {
    /// Breadth-first, one executable per layer (the PyTorch-style
    /// baseline).
    Baseline,
    /// Depth-first: detect stacks and collapse them with these options.
    BrainSlug(CollapseOptions),
}

/// Which execution backend the engine builds.
#[derive(Debug, Clone)]
pub enum BackendKind {
    /// PJRT over AOT-compiled artifacts in this directory.
    Pjrt { artifact_dir: PathBuf },
    /// The `memsim` perf-model backend — no artifacts required.
    Sim,
    /// Native in-process CPU kernels ([`CpuBackend`]): real f32
    /// execution, no artifacts, `threads` scoped workers over the
    /// depth-first band grid.
    Cpu { threads: usize },
}

impl BackendKind {
    /// Parse a CLI backend name ("pjrt" | "sim" | "cpu"). The CPU
    /// backend defaults to one thread; `--threads` overrides it.
    pub fn parse(name: &str, artifact_dir: &str) -> Result<BackendKind> {
        match name {
            "pjrt" | "xla" => Ok(BackendKind::Pjrt {
                artifact_dir: PathBuf::from(artifact_dir),
            }),
            "sim" => Ok(BackendKind::Sim),
            "cpu" | "native" => Ok(BackendKind::Cpu { threads: 1 }),
            other => bail!("unknown backend '{other}' (pjrt|sim|cpu)"),
        }
    }
}

/// Where the builder looks for tuned per-network profiles
/// ([`crate::autotune`]). Profiles only ever apply to the native CPU
/// backend in [`Mode::BrainSlug`] with *default* collapse options —
/// explicit caller-set options are never silently overridden.
#[derive(Debug, Clone, Default)]
pub enum ProfilePolicy {
    /// Load [`ProfileStore::default_path`] when the file exists
    /// (`~/.brainslug/profiles.json`). The transparent default: a
    /// `brainslug tune` run makes every later `run`/`serve` faster
    /// with zero flags.
    #[default]
    Auto,
    /// Never consult the profile cache (the autotuner itself uses this
    /// so the default-preset candidate measures the actual preset).
    Off,
    /// Load this file (CLI `--profile-path`).
    Path(PathBuf),
    /// Use an already-loaded store. The server preloads one store and
    /// shares it across worker replicas, so N workers do not re-read
    /// the cache from disk N times ([`EngineBuilder::preload_profiles`]).
    Preloaded(Arc<ProfileStore>),
}

impl ProfilePolicy {
    /// The store to consult at plan time, if any.
    fn load_store(&self) -> Option<Arc<ProfileStore>> {
        match self {
            ProfilePolicy::Off => None,
            ProfilePolicy::Auto => {
                let p = ProfileStore::default_path();
                p.exists().then(|| Arc::new(ProfileStore::load(&p)))
            }
            ProfilePolicy::Path(p) => p.exists().then(|| Arc::new(ProfileStore::load(p))),
            ProfilePolicy::Preloaded(s) => Some(s.clone()),
        }
    }

    /// Where [`EngineBuilder::autotune`] persists its winners.
    fn save_path(&self) -> Option<PathBuf> {
        match self {
            ProfilePolicy::Auto => Some(ProfileStore::default_path()),
            ProfilePolicy::Path(p) => Some(p.clone()),
            ProfilePolicy::Off | ProfilePolicy::Preloaded(_) => None,
        }
    }
}

/// Builder for [`Engine`]. `Send`, so it can be shipped to the thread
/// that will own the (non-`Send`) engine — see [`crate::server`].
#[derive(Debug, Clone)]
pub struct EngineBuilder {
    network: Option<NetworkSource>,
    device: DeviceSpec,
    mode: Mode,
    backend: BackendKind,
    /// Real-time pacing scale for the sim backend (`None` = as fast as
    /// the host allows). See [`EngineBuilder::sim_paced`].
    sim_pace: Option<f64>,
    seed: u64,
    /// Tuned-profile lookup policy (see [`ProfilePolicy`]).
    profile: ProfilePolicy,
    /// When set, `build()` runs the autotuner first and adopts (and
    /// persists) the winning configuration.
    tune: Option<TuneLevel>,
    /// Observability domain handed to the engine (spans + metrics,
    /// [`crate::obs`]). `None` = untraced: the backend hot path takes
    /// the literal pre-obs branch everywhere.
    obs: Option<Arc<crate::obs::Obs>>,
}

impl Default for EngineBuilder {
    fn default() -> Self {
        EngineBuilder {
            network: None,
            device: DeviceSpec::tpu_core(),
            mode: Mode::BrainSlug(CollapseOptions::default()),
            backend: BackendKind::Pjrt {
                artifact_dir: PathBuf::from(DEFAULT_ARTIFACT_DIR),
            },
            sim_pace: None,
            seed: DEFAULT_SEED,
            profile: ProfilePolicy::Auto,
            tune: None,
            obs: None,
        }
    }
}

impl EngineBuilder {
    /// Use a zoo architecture with an explicit [`ZooConfig`].
    pub fn zoo(mut self, name: &str, config: ZooConfig) -> Self {
        self.network = Some(NetworkSource::Zoo {
            name: zoo::resolve(name).to_string(),
            config,
        });
        self
    }

    /// Zoo architecture at reduced (measured wall-clock) scale.
    pub fn zoo_small(self, name: &str, batch: usize) -> Self {
        let cfg = zoo::small_config(name, batch);
        self.zoo(name, cfg)
    }

    /// Zoo architecture at paper (ImageNet) scale.
    pub fn zoo_paper(self, name: &str, batch: usize) -> Self {
        let cfg = zoo::paper_config(name, batch);
        self.zoo(name, cfg)
    }

    /// Use a caller-built graph.
    pub fn graph(mut self, graph: Arc<Graph>) -> Self {
        self.network = Some(NetworkSource::Graph(graph));
        self
    }

    /// Use a caller-built graph by value.
    pub fn graph_owned(self, graph: Graph) -> Self {
        self.graph(Arc::new(graph))
    }

    /// Device whose budgets drive collapse decisions (and, on the sim
    /// backend, the time model). Default: [`DeviceSpec::tpu_core`].
    pub fn device(mut self, device: DeviceSpec) -> Self {
        self.device = device;
        self
    }

    /// Set the optimization mode explicitly.
    pub fn mode(mut self, mode: Mode) -> Self {
        self.mode = mode;
        self
    }

    /// Shorthand for [`Mode::Baseline`].
    pub fn baseline(self) -> Self {
        self.mode(Mode::Baseline)
    }

    /// Shorthand for [`Mode::BrainSlug`] with `opts`.
    pub fn brainslug(self, opts: CollapseOptions) -> Self {
        self.mode(Mode::BrainSlug(opts))
    }

    /// Set the execution backend explicitly.
    pub fn backend(mut self, backend: BackendKind) -> Self {
        self.backend = backend;
        self
    }

    /// Shorthand for the PJRT backend over `artifact_dir`.
    pub fn artifacts(self, artifact_dir: impl Into<PathBuf>) -> Self {
        self.backend(BackendKind::Pjrt {
            artifact_dir: artifact_dir.into(),
        })
    }

    /// Shorthand for the artifact-free simulation backend (unpaced:
    /// `run` returns as fast as the host allows).
    pub fn sim(mut self) -> Self {
        self.sim_pace = None;
        self.backend(BackendKind::Sim)
    }

    /// Shorthand for the native CPU backend ([`CpuBackend`]): real f32
    /// kernels, no artifacts, `threads` scoped workers per kernel /
    /// depth-first band grid.
    pub fn cpu(self, threads: usize) -> Self {
        self.backend(BackendKind::Cpu { threads })
    }

    /// The simulation backend in *real-time pacing* mode: every `run`
    /// sleeps the simulated model time × `scale` before returning, so
    /// concurrency behaviour (batch occupancy, queueing, worker-pool
    /// scaling) is genuine wall-clock behaviour rather than an artifact
    /// of instantaneous runs. `scale = 1.0` replays model time 1:1;
    /// smaller scales compress it.
    pub fn sim_paced(mut self, scale: f64) -> Self {
        self.sim_pace = Some(scale);
        self.backend(BackendKind::Sim)
    }

    /// Seed for deterministic parameters and synthetic inputs.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Load tuned profiles from this file instead of the default
    /// `~/.brainslug/profiles.json` (CLI `--profile-path`).
    pub fn profile_path(mut self, path: impl Into<PathBuf>) -> Self {
        self.profile = ProfilePolicy::Path(path.into());
        self
    }

    /// Never consult the tuned-profile cache (CLI `--no-profile`).
    pub fn no_profile(mut self) -> Self {
        self.profile = ProfilePolicy::Off;
        self
    }

    /// Use an already-loaded profile store (no disk access at build
    /// time). See [`Self::preload_profiles`].
    pub fn profiles(mut self, store: Arc<ProfileStore>) -> Self {
        self.profile = ProfilePolicy::Preloaded(store);
        self
    }

    /// Read the profile cache from disk *now* and bake it in, so every
    /// later `build()` of this builder (and its clones) is disk-free.
    /// The server calls this once before fanning the builder out to N
    /// worker replicas — per-worker profile reuse instead of N reads.
    pub fn preload_profiles(mut self) -> Self {
        self.profile = match self.profile.load_store() {
            Some(store) => ProfilePolicy::Preloaded(store),
            None => ProfilePolicy::Off,
        };
        self
    }

    /// Attach an observability domain ([`crate::obs::Obs`]): engine
    /// runs record spans into it and `run_traced` attributes them to a
    /// wire trace id. Without this call the engine is untraced and the
    /// backends execute their pre-obs instruction stream (the property
    /// `fig22_trace_drift` asserts).
    pub fn obs(mut self, obs: Arc<crate::obs::Obs>) -> Self {
        self.obs = Some(obs);
        self
    }

    /// Autotune at `build()` time: search the plan space on real
    /// hardware ([`crate::autotune::tune`]), adopt the winner, and
    /// persist it to the profile cache so later builds skip the search.
    /// Requires the native CPU backend (the only one that measures).
    pub fn autotune(mut self, level: TuneLevel) -> Self {
        self.tune = Some(level);
        self
    }

    /// Resolve the network source into a graph.
    fn resolve_graph(network: Option<NetworkSource>) -> Result<Arc<Graph>> {
        match network {
            None => bail!("EngineBuilder: no network set (use .zoo()/.graph())"),
            Some(NetworkSource::Graph(g)) => Ok(g),
            Some(NetworkSource::Zoo { name, config }) => Ok(Arc::new(
                zoo::try_build(&name, config)
                    .ok_or_else(|| anyhow!("unknown network '{name}' (see `analyze --all`)"))?,
            )),
        }
    }

    /// Run the autotuner when [`Self::autotune`] was requested: adopt
    /// the winning collapse options for this backend's thread count and
    /// persist every per-thread winner to the profile cache
    /// (best-effort — an unwritable cache degrades to a warning).
    /// No-op when no tuning was requested. `pub(crate)` so the server
    /// can tune once up-front instead of once per worker replica.
    pub(crate) fn apply_autotune(mut self) -> Result<EngineBuilder> {
        let Some(level) = self.tune.take() else {
            return Ok(self);
        };
        let threads = match &self.backend {
            BackendKind::Cpu { threads } => *threads,
            other => bail!(
                "autotune requires the native CPU backend (got {other:?}); \
                 use .cpu(threads) / --backend cpu"
            ),
        };
        if !matches!(self.mode, Mode::BrainSlug(_)) {
            bail!("autotune requires BrainSlug mode (baseline has no plan to tune)");
        }
        let graph = Self::resolve_graph(self.network.take())?;
        graph
            .validate()
            .map_err(|e| anyhow!("invalid graph '{}': {e}", graph.name))?;
        let outcome = autotune::tune(&graph, &self.device, self.seed, level, &[threads])?;
        if let Some(path) = self.profile.save_path() {
            let mut store = ProfileStore::load(&path);
            for tr in &outcome.per_thread {
                store.insert(tr.profile.clone());
            }
            if let Err(e) = store.save(&path) {
                eprintln!(
                    "warning: could not persist tuning profile to {}: {e}",
                    path.display()
                );
            }
        }
        let winner = &outcome.per_thread[0];
        self.mode = Mode::BrainSlug(winner.winner.opts);
        // The winner is applied explicitly; don't re-consult the cache.
        self.profile = ProfilePolicy::Off;
        self.network = Some(NetworkSource::Graph(graph));
        Ok(self)
    }

    /// Resolve the network and optimize + validate the plan — the
    /// backend-independent half of `build`. Transparently swaps in a
    /// tuned profile's collapse options when one matches this network ×
    /// device × thread count (CPU backend, default options only).
    fn resolve(self) -> Result<Resolved> {
        let graph = Self::resolve_graph(self.network)?;
        graph
            .validate()
            .map_err(|e| anyhow!("invalid graph '{}': {e}", graph.name))?;
        let mut profile_label = None;
        let plan = match &self.mode {
            Mode::Baseline => None,
            Mode::BrainSlug(opts) => {
                let mut opts = *opts;
                if let BackendKind::Cpu { threads } = &self.backend {
                    if opts == CollapseOptions::default() {
                        if let Some(store) = self.profile.load_store() {
                            let sig = autotune::graph_signature(&graph);
                            if let Some(p) = store.get(&sig, &self.device.name, *threads) {
                                opts = p.opts;
                                profile_label = Some(format!("{} [{}]", p.describe(), p.key()));
                            }
                        }
                    }
                }
                let p = optimize(&graph, &self.device, &opts);
                p.validate(&graph)
                    .map_err(|e| anyhow!("plan validation for '{}': {e}", graph.name))?;
                // Debug builds additionally run the full static
                // verifier (resource proofs on top of the structural
                // checks `validate` already delegates to). Any
                // Severity::Error is a planner bug — reject the plan.
                if cfg!(debug_assertions) {
                    let mut diags = analysis::lint_graph(&graph);
                    diags.extend(analysis::verify_resources(&graph, &p, &self.device, &opts));
                    if let Some(d) = diags
                        .iter()
                        .find(|d| d.severity == analysis::Severity::Error)
                    {
                        bail!(
                            "static verification of plan for '{}' failed: {}",
                            graph.name,
                            d.render_oneline()
                        );
                    }
                }
                Some(Arc::new(p))
            }
        };
        Ok(Resolved {
            graph,
            plan,
            device: self.device,
            seed: self.seed,
            backend: self.backend,
            sim_pace: self.sim_pace,
            profile_label,
        })
    }

    /// Resolve the network, optimize + validate the plan, and construct
    /// the backend from the configured [`BackendKind`].
    pub fn build(self) -> Result<Engine> {
        let obs = self.obs.clone();
        let r = self.apply_autotune()?.resolve()?;
        let backend: Box<dyn Backend> = match &r.backend {
            BackendKind::Pjrt { artifact_dir } => {
                Box::new(PjrtBackend::new(artifact_dir, r.graph.clone(), r.seed)?)
            }
            BackendKind::Sim => match r.sim_pace {
                Some(scale) => Box::new(SimBackend::paced(r.device.clone(), scale)),
                None => Box::new(SimBackend::new(r.device.clone())),
            },
            BackendKind::Cpu { threads } => {
                Box::new(CpuBackend::new(r.graph.clone(), r.seed, *threads))
            }
        };
        Ok(Engine {
            graph: r.graph,
            plan: r.plan,
            device: r.device,
            seed: r.seed,
            backend,
            profile_label: r.profile_label,
            obs,
        })
    }

    /// Like [`build`](Self::build), but with a caller-supplied backend
    /// factory (receives the resolved graph, device, and seed). This is
    /// how several engines share one PJRT runtime — and its compiled-
    /// executable cache — across networks:
    /// [`PjrtBackend::with_runtime`].
    pub fn build_with<F>(self, make_backend: F) -> Result<Engine>
    where
        F: FnOnce(&Arc<Graph>, &DeviceSpec, u64) -> Result<Box<dyn Backend>>,
    {
        let obs = self.obs.clone();
        let r = self.apply_autotune()?.resolve()?;
        let backend = make_backend(&r.graph, &r.device, r.seed)?;
        Ok(Engine {
            graph: r.graph,
            plan: r.plan,
            device: r.device,
            seed: r.seed,
            backend,
            profile_label: r.profile_label,
            obs,
        })
    }
}

/// Output of [`EngineBuilder::resolve`]: everything `build` needs to
/// construct a backend and assemble the engine.
struct Resolved {
    graph: Arc<Graph>,
    plan: Option<Arc<Plan>>,
    device: DeviceSpec,
    seed: u64,
    backend: BackendKind,
    sim_pace: Option<f64>,
    profile_label: Option<String>,
}

/// The assembled pipeline: resolved graph, validated plan, and a live
/// backend. Not `Send` (PJRT internals); build one per thread from a
/// shared [`EngineBuilder`].
pub struct Engine {
    graph: Arc<Graph>,
    plan: Option<Arc<Plan>>,
    device: DeviceSpec,
    seed: u64,
    backend: Box<dyn Backend>,
    /// Description of the tuned profile the plan was built from, when
    /// one was transparently applied ([`ProfilePolicy`]).
    profile_label: Option<String>,
    /// Observability domain, when the builder armed one
    /// ([`EngineBuilder::obs`]).
    obs: Option<Arc<crate::obs::Obs>>,
}

impl Engine {
    pub fn builder() -> EngineBuilder {
        EngineBuilder::default()
    }

    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    /// Shared handle to the resolved graph (e.g. for spawning more
    /// engines over the same network).
    pub fn graph_arc(&self) -> Arc<Graph> {
        self.graph.clone()
    }

    /// The validated plan (`None` in [`Mode::Baseline`]).
    pub fn plan(&self) -> Option<&Plan> {
        self.plan.as_deref()
    }

    pub fn device(&self) -> &DeviceSpec {
        &self.device
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Description of the tuned profile this engine's plan came from
    /// (`None` when the plan uses the caller's / preset options).
    pub fn applied_profile(&self) -> Option<&str> {
        self.profile_label.as_deref()
    }

    /// Adjust the backend's worker-thread count when it has one (the
    /// native CPU backend); `false` otherwise. The existing plan is
    /// untouched — band geometry is thread-agnostic.
    pub fn set_threads(&mut self, threads: usize) -> bool {
        self.backend.set_threads(threads)
    }

    /// Deterministic synthetic input batch (the shared rng stream the
    /// python oracle also draws from).
    pub fn synthetic_input(&self) -> HostTensor {
        let seed = crate::rng::tensor_seed(self.seed, "input");
        HostTensor::from_seed(
            self.graph.input_shape().clone(),
            seed,
            crate::rng::ParamKind::Activation,
        )
    }

    /// One-line structural summary for CLI/report output.
    pub fn describe(&self) -> String {
        match &self.plan {
            Some(p) => format!(
                "network={} backend={} layers={} optimizable={} stacks={} unique_stacks={} branches={}{}",
                self.graph.name,
                self.backend.name(),
                self.graph.num_layers(),
                p.num_optimized_layers(),
                p.num_stacks(),
                p.num_unique_stacks(),
                p.num_branches(),
                if self.profile_label.is_some() {
                    " profile=tuned"
                } else {
                    ""
                }
            ),
            None => format!(
                "network={} backend={} layers={} mode=baseline",
                self.graph.name,
                self.backend.name(),
                self.graph.num_layers()
            ),
        }
    }

    fn check_input(&self, input: &HostTensor) -> Result<()> {
        let want = self.graph.input_shape();
        if &input.shape != want {
            bail!("input shape {} != network input {}", input.shape, want);
        }
        Ok(())
    }

    /// The armed observability domain, if any ([`EngineBuilder::obs`]).
    pub fn obs(&self) -> Option<&Arc<crate::obs::Obs>> {
        self.obs.as_ref()
    }

    /// Arm (or replace) the observability domain after construction —
    /// the server uses this to share one domain across worker replicas
    /// built from a cloned builder.
    pub fn set_obs(&mut self, obs: Arc<crate::obs::Obs>) {
        self.obs = Some(obs);
    }

    /// Tracing context for one run. `None` when no domain is armed, so
    /// every backend call site stays on its zero-overhead branch.
    fn obs_ctx(&self, trace: u64) -> Option<crate::obs::ObsCtx> {
        self.obs.as_ref().map(|o| crate::obs::ObsCtx {
            obs: o.clone(),
            trace,
        })
    }

    /// Execute in the configured mode (plan if [`Mode::BrainSlug`],
    /// baseline otherwise).
    pub fn run(&mut self, input: HostTensor) -> Result<(HostTensor, ExecStats)> {
        self.run_traced(input, 0)
    }

    /// Like [`run`](Self::run), attributing recorded spans to `trace`
    /// (the wire request id; 0 = unattributed). Identical to `run` when
    /// no observability domain is armed.
    pub fn run_traced(&mut self, input: HostTensor, trace: u64) -> Result<(HostTensor, ExecStats)> {
        self.check_input(&input)?;
        let work = Workload {
            graph: self.graph.clone(),
            plan: self.plan.clone(),
            seed: self.seed,
            obs: self.obs_ctx(trace),
        };
        self.backend.run(&work, input)
    }

    /// Execute breadth-first regardless of the configured mode (the
    /// comparison baseline of every experiment). Baseline runs are
    /// never traced — they are the pre-optimization comparison leg.
    pub fn run_baseline(&mut self, input: HostTensor) -> Result<(HostTensor, ExecStats)> {
        self.check_input(&input)?;
        let work = Workload {
            graph: self.graph.clone(),
            plan: None,
            seed: self.seed,
            obs: None,
        };
        self.backend.run(&work, input)
    }

    /// Paper-scale baseline simulation on the engine's device (no
    /// backend involved — pure `memsim`).
    pub fn simulate_baseline(&self) -> BaselineSim {
        simulate_baseline(&self.graph, &self.device)
    }

    /// Paper-scale plan simulation (`None` in baseline mode).
    pub fn simulate_plan(&self) -> Option<PlanSim> {
        self.plan
            .as_ref()
            .map(|p| simulate_plan(&self.graph, p, &self.device))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench;

    fn block_engine() -> EngineBuilder {
        Engine::builder()
            .graph_owned(bench::block_net(2, 2, 4, 16))
            .device(DeviceSpec::tpu_core())
            .sim()
            .seed(7)
    }

    #[test]
    fn builder_requires_network() {
        let err = Engine::builder().sim().build().unwrap_err();
        assert!(err.to_string().contains("no network"), "{err}");
    }

    #[test]
    fn unknown_zoo_name_errors() {
        let err = Engine::builder()
            .zoo_small("nope", 1)
            .sim()
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("unknown network"), "{err}");
    }

    #[test]
    fn zoo_alias_resolves_through_builder() {
        let eng = Engine::builder().zoo_small("vgg", 1).sim().build().unwrap();
        assert_eq!(eng.graph().name, "vgg16");
        assert_eq!(eng.backend_name(), "sim");
    }

    #[test]
    fn sim_engine_runs_both_modes_with_identical_outputs() {
        let mut eng = block_engine().build().unwrap();
        assert!(eng.plan().is_some());
        let input = eng.synthetic_input();
        let (out_base, stats_base) = eng.run_baseline(input.clone()).unwrap();
        let (out_plan, stats_plan) = eng.run(input).unwrap();
        // Sim outputs are a pure function of the seed: modes agree.
        assert_eq!(out_base, out_plan);
        assert_eq!(out_base.shape, *eng.graph().output_shape());
        // Baseline stats: one entry per non-input layer.
        assert_eq!(stats_base.segments.len(), eng.graph().num_layers());
        // Plan stats: the whole block net collapses into one stack.
        assert!(stats_plan.segments.iter().any(|s| s.kind == "stack"));
        assert!(stats_base.total_s > 0.0 && stats_plan.total_s > 0.0);
    }

    #[test]
    fn sim_stats_match_memsim_totals() {
        let mut eng = block_engine().build().unwrap();
        let input = eng.synthetic_input();
        let (_, stats_base) = eng.run_baseline(input.clone()).unwrap();
        let (_, stats_plan) = eng.run(input).unwrap();
        let base = eng.simulate_baseline();
        let plan = eng.simulate_plan().unwrap();
        assert!((stats_base.total_s - base.total_s).abs() < 1e-12 * base.total_s.max(1.0));
        assert!((stats_plan.total_s - plan.total_s).abs() < 1e-12 * plan.total_s.max(1.0));
    }

    #[test]
    fn baseline_mode_has_no_plan() {
        let eng = Engine::builder()
            .graph_owned(bench::block_net(1, 1, 2, 8))
            .baseline()
            .sim()
            .build()
            .unwrap();
        assert!(eng.plan().is_none());
        assert!(eng.simulate_plan().is_none());
        assert!(eng.describe().contains("mode=baseline"));
    }

    #[test]
    fn engine_rejects_wrong_input_shape() {
        let mut eng = block_engine().build().unwrap();
        let bad = HostTensor::zeros(crate::graph::Shape::nf(1, 3));
        assert!(eng.run(bad).is_err());
    }

    #[test]
    fn paced_sim_sleeps_scaled_model_time() {
        // Calibrate against the unpaced model time so the assertion is
        // device-model independent: a paced run must take at least the
        // model time × scale of wall-clock.
        let mut plain = block_engine().build().unwrap();
        let input = plain.synthetic_input();
        let (_, st) = plain.run(input).unwrap();
        let target = 0.02; // 20 ms per run
        let scale = target / st.total_s.max(1e-12);
        let mut paced = block_engine().sim_paced(scale).build().unwrap();
        let input = paced.synthetic_input();
        let t0 = std::time::Instant::now();
        let (_, st_paced) = paced.run(input).unwrap();
        // Pacing changes wall-clock, never the reported model time.
        assert!((st_paced.total_s - st.total_s).abs() < 1e-12 * st.total_s.max(1.0));
        assert!(
            t0.elapsed().as_secs_f64() >= target * 0.9,
            "paced run returned faster than the pacing floor"
        );
    }

    #[test]
    fn engine_obs_traces_runs_and_baseline_stays_untraced() {
        let obs = Arc::new(crate::obs::Obs::default());
        let mut eng = Engine::builder()
            .graph_owned(bench::block_net(2, 1, 2, 12))
            .device(DeviceSpec::host_cpu())
            .cpu(1)
            .obs(obs.clone())
            .seed(3)
            .build()
            .unwrap();
        assert!(eng.obs().is_some());
        let input = eng.synthetic_input();
        eng.run_traced(input, 0x77).unwrap();
        let spans = obs.spans.drain();
        assert!(spans.iter().any(|s| s.kind == crate::obs::SpanKind::Plan));
        assert!(spans.iter().all(|s| s.trace == 0x77), "all spans carry the trace id");
        let input = eng.synthetic_input();
        eng.run_baseline(input).unwrap();
        assert!(obs.spans.drain().is_empty(), "baseline leg records nothing");
    }

    #[test]
    fn builder_is_send() {
        fn assert_send<T: Send>() {}
        assert_send::<EngineBuilder>();
    }

    #[test]
    fn backend_kind_parses() {
        assert!(matches!(
            BackendKind::parse("sim", "artifacts").unwrap(),
            BackendKind::Sim
        ));
        assert!(matches!(
            BackendKind::parse("pjrt", "x").unwrap(),
            BackendKind::Pjrt { .. }
        ));
        assert!(matches!(
            BackendKind::parse("cpu", "x").unwrap(),
            BackendKind::Cpu { threads: 1 }
        ));
        assert!(BackendKind::parse("fpga", "x").is_err());
    }

    #[test]
    fn cpu_engine_runs_both_modes_with_identical_outputs() {
        // The native backend really computes: baseline (breadth-first
        // kernels) and depth-first (band walker) must agree exactly on
        // a fully-optimizable block net.
        let mut eng = Engine::builder()
            .graph_owned(bench::block_net(2, 2, 4, 16))
            .device(DeviceSpec::host_cpu())
            .cpu(2)
            .seed(7)
            .build()
            .unwrap();
        assert_eq!(eng.backend_name(), "cpu");
        assert!(eng.plan().is_some());
        let input = eng.synthetic_input();
        let (out_base, stats_base) = eng.run_baseline(input.clone()).unwrap();
        let (out_plan, stats_plan) = eng.run(input).unwrap();
        assert_eq!(out_base, out_plan);
        assert_eq!(out_base.shape, *eng.graph().output_shape());
        assert_eq!(stats_base.segments.len(), eng.graph().num_layers());
        assert!(stats_plan.segments.iter().any(|s| s.kind == "stack"));
    }

    fn tmp_profile_path(name: &str) -> PathBuf {
        std::env::temp_dir()
            .join(format!("brainslug_engine_{}_{name}", std::process::id()))
            .join("profiles.json")
    }

    #[test]
    fn builder_applies_matching_profile_transparently() {
        // Hand-write a profile for the block net and check the builder
        // picks it up (cpu backend + default opts), that the plan really
        // reflects the tuned config, and that key mismatches miss.
        let g = Arc::new(bench::block_net(2, 2, 4, 16));
        let device = DeviceSpec::host_cpu();
        let path = tmp_profile_path("apply");
        let mut store = crate::autotune::ProfileStore::default();
        store.insert(crate::autotune::Profile {
            network: g.name.clone(),
            signature: crate::autotune::graph_signature(&g),
            device: device.name.clone(),
            threads: 2,
            opts: CollapseOptions {
                max_tile_rows: Some(1),
                ..Default::default()
            },
            tuned_s: 1.0,
            default_s: 2.0,
        });
        store.save(&path).unwrap();

        let eng = Engine::builder()
            .graph(g.clone())
            .device(device.clone())
            .cpu(2)
            .profile_path(&path)
            .build()
            .unwrap();
        assert!(eng.applied_profile().is_some(), "profile must apply");
        assert!(eng.describe().contains("profile=tuned"));
        for st in eng.plan().unwrap().stacks() {
            for seq in &st.sequences {
                assert_eq!(seq.tile_rows, 1, "tuned tile cap not honoured");
            }
        }
        // Thread-count mismatch: no application.
        let eng1 = Engine::builder()
            .graph(g.clone())
            .device(device.clone())
            .cpu(1)
            .profile_path(&path)
            .build()
            .unwrap();
        assert!(eng1.applied_profile().is_none());
        // Explicit opt-out.
        let eng2 = Engine::builder()
            .graph(g.clone())
            .device(device.clone())
            .cpu(2)
            .profile_path(&path)
            .no_profile()
            .build()
            .unwrap();
        assert!(eng2.applied_profile().is_none());
        // Caller-set (non-default) options are never overridden.
        let eng3 = Engine::builder()
            .graph(g.clone())
            .device(device.clone())
            .brainslug(CollapseOptions {
                min_tile_rows: 2,
                ..Default::default()
            })
            .cpu(2)
            .profile_path(&path)
            .build()
            .unwrap();
        assert!(eng3.applied_profile().is_none());
        // Preloading bakes the store in (still applies, no disk read).
        let eng4 = Engine::builder()
            .graph(g.clone())
            .device(device)
            .cpu(2)
            .profile_path(&path)
            .preload_profiles()
            .build()
            .unwrap();
        assert!(eng4.applied_profile().is_some());
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn builder_autotune_applies_persists_and_reloads() {
        let path = tmp_profile_path("autotune");
        let mk = || {
            Engine::builder()
                .graph_owned(bench::block_net(2, 1, 2, 12))
                .device(DeviceSpec::host_cpu())
                .cpu(1)
                .profile_path(&path)
                .seed(3)
        };
        let mut eng = mk().autotune(crate::autotune::TuneLevel::Fast).build().unwrap();
        assert!(path.exists(), "autotune must persist its winner");
        // The tuned engine still satisfies parity.
        let input = eng.synthetic_input();
        let (base, _) = eng.run_baseline(input.clone()).unwrap();
        let (df, _) = eng.run(input).unwrap();
        assert_eq!(base, df, "tuned schedule diverges");
        // A fresh builder over the same cache transparently reloads it.
        let eng2 = mk().build().unwrap();
        assert!(eng2.applied_profile().is_some());
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn autotune_requires_the_cpu_backend() {
        let err = Engine::builder()
            .graph_owned(bench::block_net(1, 1, 2, 8))
            .sim()
            .autotune(crate::autotune::TuneLevel::Fast)
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("CPU backend"), "{err}");
    }

    #[test]
    fn cpu_engine_runs_a_zoo_network_end_to_end() {
        // Conv, pool, flatten, linear, branch joins — the whole layer
        // inventory — on a tiny resnet18 instance.
        let cfg = crate::zoo::ZooConfig {
            batch: 1,
            input: 32,
            width_mult: 0.125,
            num_classes: 4,
        };
        let mut eng = Engine::builder()
            .zoo("resnet18", cfg)
            .device(DeviceSpec::host_cpu())
            .cpu(2)
            .seed(3)
            .build()
            .unwrap();
        let input = eng.synthetic_input();
        let (out_base, _) = eng.run_baseline(input.clone()).unwrap();
        let (out_plan, _) = eng.run(input).unwrap();
        assert_eq!(out_base.shape.dims, vec![1, 4]);
        assert!(
            out_base.allclose(&out_plan, 1e-6, 1e-6),
            "max |diff| = {:.3e}",
            out_base.max_abs_diff(&out_plan)
        );
    }
}
