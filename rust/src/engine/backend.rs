//! Execution backends behind the [`Engine`](super::Engine) facade.
//!
//! A [`Backend`] turns a [`Workload`] (graph + optional plan + seed) and
//! an input batch into an output batch plus per-segment [`ExecStats`].
//! Three implementations ship:
//!
//! * [`PjrtBackend`] — the PJRT runtime executing AOT-compiled
//!   XLA/Pallas artifacts through the scheduler. Numerics are identical
//!   to the pre-facade `Runtime` + `Executor` wiring.
//! * [`SimBackend`] — the artifact-free path: drives the `memsim`
//!   analytic perf model, reporting the simulated per-segment times as
//!   `ExecStats` and synthesizing a deterministic output tensor. `run`,
//!   `serve`, and the benches work end-to-end with no `artifacts/`
//!   directory (batching behaviour, plan structure, and stats plumbing
//!   are all real; only the tensor math is simulated).
//! * [`crate::cpu::CpuBackend`] — artifact-free *and* real: native f32
//!   kernels execute the breadth-first baseline, the depth-first band
//!   walker executes collapsed stacks (see [`crate::cpu`]). This is the
//!   backend that turns the perf claims into measured wall-clock.

use std::path::Path;
use std::rc::Rc;
use std::sync::Arc;

use anyhow::Result;

use crate::device::DeviceSpec;
use crate::graph::Graph;
use crate::memsim::{segment_times, simulate_baseline, ModelParams};
use crate::optimizer::Plan;
use crate::runtime::{HostTensor, Runtime};
use crate::scheduler::{ExecStats, Executor};

/// Everything a backend needs to execute one network: the resolved
/// graph, the validated plan (`None` = breadth-first baseline), the
/// deterministic parameter seed, and the optional tracing context.
#[derive(Clone)]
pub struct Workload {
    pub graph: Arc<Graph>,
    pub plan: Option<Arc<Plan>>,
    pub seed: u64,
    /// Armed observability context ([`crate::obs`]): when `Some`, the
    /// backend records Plan/Segment/Band/Kernel spans attributed to
    /// `obs.trace`. `None` (the default) is the zero-overhead path —
    /// backends that ignore tracing (PJRT, sim) never look at it.
    pub obs: Option<crate::obs::ObsCtx>,
}

/// An execution strategy for optimized (or baseline) workloads.
pub trait Backend {
    /// Short identifier ("pjrt", "sim") for logs and reports.
    fn name(&self) -> &'static str;

    /// Execute `work` on `input`, returning the output batch and
    /// per-segment statistics.
    fn run(&mut self, work: &Workload, input: HostTensor) -> Result<(HostTensor, ExecStats)>;

    /// Adjust the worker-thread count, when the backend has one
    /// ([`crate::cpu::CpuBackend`] does; the PJRT and sim backends
    /// return `false`). The autotuner uses this to sweep the thread
    /// dimension on one live backend instead of rebuilding parameter
    /// caches per thread count.
    fn set_threads(&mut self, _threads: usize) -> bool {
        false
    }
}

/// The PJRT backend: wraps today's [`Runtime`] + [`Executor`] pair. The
/// executor (and its deterministic parameter cache) persists across
/// `run` calls, so repeated measurements only pay for execution.
///
/// The backend is *bound* to one graph + seed at construction (that is
/// what the executor's parameter cache is keyed on); `run` rejects a
/// workload carrying a different graph or seed rather than silently
/// executing the bound one.
pub struct PjrtBackend {
    runtime: Rc<Runtime>,
    graph: Arc<Graph>,
    seed: u64,
    exec: Executor,
}

impl PjrtBackend {
    /// Load the artifact manifest at `artifact_dir` and prepare an
    /// executor for `graph`. Fails if the manifest is missing (run
    /// `make artifacts`).
    pub fn new(artifact_dir: &Path, graph: Arc<Graph>, seed: u64) -> Result<Self> {
        let runtime = Rc::new(Runtime::new(artifact_dir)?);
        Ok(Self::with_runtime(runtime, graph, seed))
    }

    /// Prepare an executor for `graph` over an existing runtime, so
    /// several engines can share one compiled-executable cache (the
    /// measured benches build many engines against one artifact dir).
    pub fn with_runtime(runtime: Rc<Runtime>, graph: Arc<Graph>, seed: u64) -> Self {
        let exec = Executor::new(runtime.clone(), graph.clone(), seed);
        PjrtBackend {
            runtime,
            graph,
            seed,
            exec,
        }
    }

    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn run(&mut self, work: &Workload, input: HostTensor) -> Result<(HostTensor, ExecStats)> {
        anyhow::ensure!(
            Arc::ptr_eq(&work.graph, &self.graph),
            "PjrtBackend is bound to graph '{}'; rebuild the backend for a different network",
            self.graph.name
        );
        anyhow::ensure!(
            work.seed == self.seed,
            "PjrtBackend is bound to seed {}; workload asks for {}",
            self.seed,
            work.seed
        );
        match &work.plan {
            Some(p) => self.exec.run_plan(p, input),
            None => self.exec.run_baseline(input),
        }
    }
}

/// The simulation backend: no artifacts, no PJRT. Per-segment times come
/// from the `memsim` analytic model for the configured device; the
/// output tensor is a deterministic function of the workload seed (and
/// therefore identical between baseline and plan runs, which keeps the
/// facade's numerics cross-checks trivially green).
///
/// In *paced* mode ([`SimBackend::paced`], reachable through
/// [`EngineBuilder::sim_paced`](super::EngineBuilder::sim_paced)) each
/// `run` additionally sleeps the simulated total time × the pacing
/// scale, so a run occupies real wall-clock proportional to its model
/// cost. Concurrency experiments (the serving worker pool, queueing
/// backpressure) need this: with instantaneous runs every queue is
/// always empty and scaling measurements are artifacts.
pub struct SimBackend {
    device: DeviceSpec,
    params: ModelParams,
    /// Wall-clock seconds slept per simulated second (`None` = unpaced).
    pace_scale: Option<f64>,
}

impl SimBackend {
    pub fn new(device: DeviceSpec) -> Self {
        let params = ModelParams::for_device(&device);
        SimBackend {
            device,
            params,
            pace_scale: None,
        }
    }

    /// Paced mode: sleep `model_time × scale` per `run`.
    pub fn paced(device: DeviceSpec, scale: f64) -> Self {
        SimBackend {
            pace_scale: Some(scale),
            ..Self::new(device)
        }
    }

    pub fn device(&self) -> &DeviceSpec {
        &self.device
    }

    pub fn pace_scale(&self) -> Option<f64> {
        self.pace_scale
    }
}

impl Backend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn run(&mut self, work: &Workload, _input: HostTensor) -> Result<(HostTensor, ExecStats)> {
        let graph = &*work.graph;
        let mut stats = ExecStats::default();
        match &work.plan {
            None => {
                let sim = simulate_baseline(graph, &self.device);
                for lt in sim.per_layer {
                    stats.push(lt.name, lt.kind.into(), lt.seconds, lt.optimizable);
                }
            }
            Some(plan) => {
                // One shared walk with the memsim plan simulation
                // (branch arms depth-first, join fused): reported stats
                // and `simulate_plan` totals agree by construction.
                let mut times = Vec::new();
                for seg in &plan.segments {
                    segment_times(graph, seg, &self.device, &self.params, &mut times);
                }
                for lt in times {
                    stats.push(lt.name, lt.kind.into(), lt.seconds, lt.optimizable);
                }
            }
        }
        if let Some(scale) = self.pace_scale {
            let secs = stats.total_s * scale;
            if secs > 0.0 && secs.is_finite() {
                std::thread::sleep(std::time::Duration::from_secs_f64(secs));
            }
        }
        let out_seed = crate::rng::tensor_seed(work.seed, "sim:output");
        let out = HostTensor::from_seed(
            graph.output_shape().clone(),
            out_seed,
            crate::rng::ParamKind::Activation,
        );
        Ok((out, stats))
    }
}
