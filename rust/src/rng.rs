//! Deterministic parameter/data generation shared with the python side.
//!
//! Network parameters (conv weights, BN statistics, …) and synthetic
//! inputs must be *identical* in the rust runtime and in the python
//! oracle so that scheduler outputs can be cross-checked numerically.
//! Both sides implement the same SplitMix64 stream → f32 mapping; see
//! `python/compile/detrng.py` and the golden-file test
//! `rust/tests/detrng_golden.rs`.

/// SplitMix64 step (Steele et al.): advances the state and returns the
/// mixed output.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Map a SplitMix64 output to f32 uniform in [-1, 1): the top 24 bits
/// become a fraction of 2^23, offset by -1. Exactly representable, so the
/// python mirror reproduces it bit-for-bit.
#[inline]
pub fn u64_to_f32(x: u64) -> f32 {
    ((x >> 40) as f32) / (1u32 << 23) as f32 - 1.0
}

/// Fill a fresh vector with `n` deterministic f32 values for `seed`.
pub fn fill_f32(seed: u64, n: usize) -> Vec<f32> {
    let mut state = seed;
    (0..n).map(|_| u64_to_f32(splitmix64(&mut state))).collect()
}

/// Derive a per-tensor seed from a network seed and a stable tag (node
/// name + param index). FNV-1a over the tag, mixed with the base seed.
pub fn tensor_seed(base: u64, tag: &str) -> u64 {
    let mut h: u64 = 0xCBF29CE484222325;
    for b in tag.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001B3);
    }
    h ^ base.rotate_left(17)
}

/// Deterministic "plausible" parameter fill: uniform [-1,1) scaled down
/// for weights; BN running-var is shifted positive. `kind` selects the
/// post-processing and must match `python/compile/detrng.py`.
pub fn fill_param(seed: u64, n: usize, kind: ParamKind) -> Vec<f32> {
    let raw = fill_f32(seed, n);
    match kind {
        ParamKind::Weight => raw.iter().map(|v| v * 0.1).collect(),
        ParamKind::Bias => raw.iter().map(|v| v * 0.01).collect(),
        ParamKind::BnGamma => raw.iter().map(|v| 1.0 + v * 0.1).collect(),
        ParamKind::BnBeta => raw.iter().map(|v| v * 0.01).collect(),
        ParamKind::BnMean => raw.iter().map(|v| v * 0.1).collect(),
        // strictly positive, well away from eps
        ParamKind::BnVar => raw.iter().map(|v| 0.55 + v * 0.45).collect(),
        ParamKind::Activation => raw,
    }
}

/// Parameter post-processing kinds (mirrored in python).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParamKind {
    Weight,
    Bias,
    BnGamma,
    BnBeta,
    BnMean,
    BnVar,
    Activation,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // First outputs for seed 0 (reference vector from the SplitMix64
        // paper implementation).
        let mut s = 0u64;
        assert_eq!(splitmix64(&mut s), 0xE220A8397B1DCDAF);
        assert_eq!(splitmix64(&mut s), 0x6E789E6AA1B965F4);
        assert_eq!(splitmix64(&mut s), 0x06C45D188009454F);
    }

    #[test]
    fn f32_mapping_range() {
        for v in fill_f32(42, 10_000) {
            assert!((-1.0..1.0).contains(&v));
        }
        assert_eq!(u64_to_f32(0), -1.0);
        // max 24-bit fraction: (2^24 - 1)/2^23 - 1 just below 1.
        assert!(u64_to_f32(u64::MAX) < 1.0);
    }

    #[test]
    fn deterministic() {
        assert_eq!(fill_f32(7, 100), fill_f32(7, 100));
        assert_ne!(fill_f32(7, 100), fill_f32(8, 100));
    }

    #[test]
    fn tensor_seed_stable_and_distinct() {
        let a = tensor_seed(1, "conv1.w0");
        assert_eq!(a, tensor_seed(1, "conv1.w0"));
        assert_ne!(a, tensor_seed(1, "conv1.w1"));
        assert_ne!(a, tensor_seed(2, "conv1.w0"));
    }

    #[test]
    fn bn_var_strictly_positive() {
        for v in fill_param(3, 1000, ParamKind::BnVar) {
            assert!(v > 0.05, "var {v} too small");
        }
    }
}
