//! Minimal JSON substrate (parser + writer + lazy field scanner).
//!
//! The build environment is offline without serde, so the artifact
//! manifest, graph interop with the python compile path, report
//! emission, and the HTTP serving front door use this small, strict
//! JSON implementation instead. It supports the full JSON grammar
//! except `\u` surrogate pairs beyond the BMP are passed through
//! unvalidated.
//!
//! Two entry styles:
//!
//! * [`parse`] — full tree into [`Json`] (manifest/graph documents).
//! * [`scan_str_field`] / [`scan_f32_array_field`] — *lazy* single-field
//!   extraction for the serving hot path: scan the top-level object for
//!   one key and decode only that value, skipping every other field
//!   structurally without allocating a tree (the mik-sdk ADR-002 /
//!   smoljson idiom). A `POST /v1/run` body is one large `"input"`
//!   array plus a couple of small fields; the scanners turn it straight
//!   into a `Vec<f32>` with no intermediate `Json` values at all.
//!
//! Both entries enforce [`MAX_DEPTH`], so a hostile `[[[[…` request
//! body cannot exhaust the parser's recursion stack.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects use a BTreeMap so emission is deterministic
/// (stable key order), which the golden-file tests rely on.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- constructors ----

    pub fn object() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_usize(v: usize) -> Json {
        Json::Num(v as f64)
    }

    /// Insert into an object (panics if not an object).
    pub fn set(&mut self, key: &str, value: Json) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value);
            }
            _ => panic!("set() on non-object"),
        }
        self
    }

    // ---- accessors ----

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Field access that errors with the key name (for manifest parsing).
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing field '{key}'"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Typed helpers for manifest decoding.
    pub fn str_field(&self, key: &str) -> anyhow::Result<String> {
        Ok(self
            .req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' not a string"))?
            .to_string())
    }

    pub fn usize_field(&self, key: &str) -> anyhow::Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' not a non-negative integer"))
    }

    pub fn f64_field(&self, key: &str) -> anyhow::Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' not a number"))
    }

    pub fn bool_field(&self, key: &str) -> anyhow::Result<bool> {
        self.req(key)?
            .as_bool()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' not a bool"))
    }

    pub fn arr_field(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' not an array"))
    }

    /// Decode `[1,2,3]` into a usize vector.
    pub fn usize_vec(&self) -> anyhow::Result<Vec<usize>> {
        self.as_arr()
            .ok_or_else(|| anyhow::anyhow!("not an array"))?
            .iter()
            .map(|j| j.as_usize().ok_or_else(|| anyhow::anyhow!("not a usize")))
            .collect()
    }

    // ---- emission ----

    /// Compact single-line rendering.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty rendering with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, level: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    item.write(out, indent, level + 1);
                }
                newline_indent(out, indent, level);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, level + 1);
                }
                newline_indent(out, indent, level);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, level: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * level {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Maximum nesting depth accepted by [`parse`] and the lazy scanners:
/// deep enough for any manifest/graph/bench document this repo emits,
/// shallow enough that recursion stays bounded on hostile input.
pub const MAX_DEPTH: usize = 128;

/// Parse a JSON document. Returns an error with byte position context.
pub fn parse(input: &str) -> anyhow::Result<Json> {
    let mut p = Parser::new(input);
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        anyhow::bail!("trailing garbage at byte {}", p.pos);
    }
    Ok(v)
}

/// Lazily extract the string value of top-level field `key` without
/// building a tree: every other field is skipped structurally. Returns
/// `Ok(None)` when the key is absent, an error when the document (up to
/// and including the match) is malformed or the value is not a string.
///
/// Lazy means *lazy*: bytes after the matched value are never looked
/// at, so garbage in later fields goes undetected — acceptable for the
/// serving hot path, where the alternative is parsing a megabyte of
/// `"input"` numbers twice.
pub fn scan_str_field(input: &str, key: &str) -> anyhow::Result<Option<String>> {
    let mut p = Parser::new(input);
    if !p.seek_top_level(key)? {
        return Ok(None);
    }
    if p.peek()? != b'"' {
        anyhow::bail!("field '{key}' not a string");
    }
    Ok(Some(p.string()?))
}

/// Lazily extract top-level field `key` as a flat `f32` array (the
/// `POST /v1/run` `"input"` payload): numbers are decoded straight into
/// the vector, no `Json` values are built anywhere. `Ok(None)` when the
/// key is absent; an error when the value is not an array of numbers.
pub fn scan_f32_array_field(input: &str, key: &str) -> anyhow::Result<Option<Vec<f32>>> {
    let mut p = Parser::new(input);
    if !p.seek_top_level(key)? {
        return Ok(None);
    }
    if p.peek()? != b'[' {
        anyhow::bail!("field '{key}' not an array");
    }
    p.pos += 1;
    let mut out = Vec::new();
    p.skip_ws();
    if p.peek()? == b']' {
        p.pos += 1;
        return Ok(Some(out));
    }
    loop {
        p.skip_ws();
        match p.peek()? {
            b'-' | b'0'..=b'9' => {
                let n = p
                    .number()?
                    .as_f64()
                    .expect("number() always yields Num");
                out.push(n as f32);
            }
            _ => anyhow::bail!(
                "field '{key}' must be a flat array of numbers (byte {})",
                p.pos
            ),
        }
        p.skip_ws();
        match p.peek()? {
            b',' => {
                p.pos += 1;
            }
            b']' => {
                p.pos += 1;
                return Ok(Some(out));
            }
            c => anyhow::bail!("expected ',' or ']' at byte {}, got '{}'", p.pos, c as char),
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn new(input: &'a str) -> Parser<'a> {
        Parser {
            bytes: input.as_bytes(),
            pos: 0,
            depth: 0,
        }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> anyhow::Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of input at byte {}", self.pos))
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        if self.peek()? == b {
            self.pos += 1;
            Ok(())
        } else {
            anyhow::bail!(
                "expected '{}' at byte {}, found '{}'",
                b as char,
                self.pos,
                self.peek()? as char
            )
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> anyhow::Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            anyhow::bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.enter()?;
        let v = self.value_inner();
        self.depth -= 1;
        v
    }

    fn value_inner(&mut self) -> anyhow::Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => anyhow::bail!("unexpected '{}' at byte {}", c as char, self.pos),
        }
    }

    /// Bump the recursion depth, erroring past [`MAX_DEPTH`].
    fn enter(&mut self) -> anyhow::Result<()> {
        if self.depth >= MAX_DEPTH {
            anyhow::bail!(
                "nesting deeper than {MAX_DEPTH} levels at byte {}",
                self.pos
            );
        }
        self.depth += 1;
        Ok(())
    }

    // ---- lazy scanning (no tree construction) ----

    /// Scan the document's top-level object for `key`. On a hit the
    /// cursor rests on the first byte of the value and `Ok(true)` is
    /// returned; other fields' values are skipped structurally (no
    /// allocation beyond each key string). `Ok(false)` when the object
    /// ends without the key.
    fn seek_top_level(&mut self, key: &str) -> anyhow::Result<bool> {
        self.skip_ws();
        self.expect(b'{')?;
        self.skip_ws();
        if self.peek()? == b'}' {
            return Ok(false);
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            if k == key {
                return Ok(true);
            }
            self.skip_value()?;
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b'}' => return Ok(false),
                c => anyhow::bail!(
                    "expected ',' or '}}' at byte {}, got '{}'",
                    self.pos,
                    c as char
                ),
            }
        }
    }

    /// Advance past one value without building it. Escape sequences in
    /// skipped strings are not validated (only `\"`/`\\` matter for
    /// finding the closing quote); nesting still honours [`MAX_DEPTH`].
    fn skip_value(&mut self) -> anyhow::Result<()> {
        self.enter()?;
        let r = self.skip_value_inner();
        self.depth -= 1;
        r
    }

    fn skip_value_inner(&mut self) -> anyhow::Result<()> {
        match self.peek()? {
            b'"' => self.skip_string(),
            b'{' => self.skip_delimited(b'}', true),
            b'[' => self.skip_delimited(b']', false),
            b't' => self.literal("true", Json::Null).map(|_| ()),
            b'f' => self.literal("false", Json::Null).map(|_| ()),
            b'n' => self.literal("null", Json::Null).map(|_| ()),
            b'-' | b'0'..=b'9' => self.number().map(|_| ()),
            c => anyhow::bail!("unexpected '{}' at byte {}", c as char, self.pos),
        }
    }

    /// Skip an object (`with_keys`) or array body up to `close`.
    fn skip_delimited(&mut self, close: u8, with_keys: bool) -> anyhow::Result<()> {
        self.pos += 1; // opening brace/bracket, already peeked
        self.skip_ws();
        if self.peek()? == close {
            self.pos += 1;
            return Ok(());
        }
        loop {
            self.skip_ws();
            if with_keys {
                self.skip_string()?;
                self.skip_ws();
                self.expect(b':')?;
                self.skip_ws();
            }
            self.skip_value()?;
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                c if c == close => {
                    self.pos += 1;
                    return Ok(());
                }
                c => anyhow::bail!(
                    "expected ',' or '{}' at byte {}, got '{}'",
                    close as char,
                    self.pos,
                    c as char
                ),
            }
        }
    }

    fn skip_string(&mut self) -> anyhow::Result<()> {
        self.expect(b'"')?;
        loop {
            let c = self.peek()?;
            self.pos += 1;
            match c {
                b'"' => return Ok(()),
                b'\\' => {
                    // Consume the escape head so an escaped quote does
                    // not terminate the scan; `\uXXXX` hex digits fall
                    // through the generic arm.
                    self.peek()?;
                    self.pos += 1;
                }
                _ => {}
            }
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                c => anyhow::bail!("expected ',' or '}}' at byte {}, got '{}'", self.pos, c as char),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                c => anyhow::bail!("expected ',' or ']' at byte {}, got '{}'", self.pos, c as char),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                anyhow::bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        c => anyhow::bail!("bad escape '\\{}'", c as char),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                _ => {
                    // Multi-byte UTF-8: re-decode from the byte slice.
                    let start = self.pos - 1;
                    let slice = &self.bytes[start..];
                    let ch = std::str::from_utf8(&slice[..slice.len().min(4)])
                        .ok()
                        .and_then(|t| t.chars().next())
                        .ok_or_else(|| anyhow::anyhow!("invalid utf-8 at byte {start}"))?;
                    s.push(ch);
                    self.pos = start + ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        if self.peek()? == b'-' {
            self.pos += 1;
        }
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| {
            anyhow::anyhow!("bad number '{text}' at byte {start}: {e}")
        })?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-12", "3.5", "\"hi\""] {
            let v = parse(src).unwrap();
            assert_eq!(parse(&v.to_string_compact()).unwrap(), v, "{src}");
        }
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a":[1,2,{"b":null}],"c":"x\ny","d":{"e":[]},"f":1e3}"#;
        let v = parse(src).unwrap();
        let emitted = v.to_string_compact();
        assert_eq!(parse(&emitted).unwrap(), v);
        assert_eq!(v.get("f").unwrap().as_f64().unwrap(), 1000.0);
    }

    #[test]
    fn object_builder_and_accessors() {
        let mut o = Json::object();
        o.set("n", Json::from_usize(42))
            .set("s", Json::Str("x".into()))
            .set("a", Json::Arr(vec![Json::from_usize(1), Json::from_usize(2)]));
        assert_eq!(o.usize_field("n").unwrap(), 42);
        assert_eq!(o.str_field("s").unwrap(), "x");
        assert_eq!(o.arr_field("a").unwrap().len(), 2);
        assert_eq!(
            o.get("a").unwrap().usize_vec().unwrap(),
            vec![1, 2]
        );
        assert!(o.usize_field("missing").is_err());
    }

    #[test]
    fn deterministic_key_order() {
        let v = parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        assert_eq!(v.to_string_compact(), r#"{"a":2,"m":3,"z":1}"#);
    }

    #[test]
    fn escapes() {
        let v = Json::Str("a\"b\\c\nd\u{1}".into());
        let s = v.to_string_compact();
        assert_eq!(s, "\"a\\\"b\\\\c\\nd\\u0001\"");
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ☃");
        let u = parse("\"\\u2603\"").unwrap();
        assert_eq!(u.as_str().unwrap(), "☃");
    }

    #[test]
    fn errors() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("01a").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("{} extra").is_err());
    }

    #[test]
    fn pretty_is_parseable() {
        let v = parse(r#"{"a":[1,2],"b":{"c":true}}"#).unwrap();
        assert_eq!(parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn large_integers_exact() {
        let v = parse("123456789012345").unwrap();
        assert_eq!(v.as_usize().unwrap(), 123456789012345);
        assert_eq!(v.to_string_compact(), "123456789012345");
    }

    #[test]
    fn escape_sequence_roundtrips() {
        // Read side: every escape form decodes to the expected char.
        for (src, want) in [
            (r#""\"""#, "\""),
            (r#""\\""#, "\\"),
            (r#""\n""#, "\n"),
            (r#""\t""#, "\t"),
            (r#""\r""#, "\r"),
            (r#""\/""#, "/"),
            (r#""A""#, "A"),
            (r#""☃""#, "☃"),
            ("\"\\u0041\"", "A"),
            ("\"\\u2603\"", "☃"),
        ] {
            let v = parse(src).unwrap();
            assert_eq!(v.as_str().unwrap(), want, "{src}");
            // Write side: emitting and re-parsing preserves the value.
            assert_eq!(parse(&v.to_string_compact()).unwrap(), v, "{src}");
        }
        // Escapes embedded in keys survive the object round trip.
        let doc = "{\"a\\nb\":1}";
        let v = parse(doc).unwrap();
        assert_eq!(v.get("a\nb").unwrap().as_usize().unwrap(), 1);
        assert_eq!(parse(&v.to_string_compact()).unwrap(), v);
    }

    #[test]
    fn depth_limit_rejects_nesting_bombs() {
        let deep = |n: usize| format!("{}0{}", "[".repeat(n), "]".repeat(n));
        assert!(parse(&deep(MAX_DEPTH - 1)).is_ok());
        let err = parse(&deep(MAX_DEPTH + 50)).unwrap_err().to_string();
        assert!(err.contains("nesting"), "{err}");
        // Object nesting hits the same guard.
        let objs = format!("{}1{}", "{\"k\":".repeat(MAX_DEPTH + 50), "}".repeat(MAX_DEPTH + 50));
        assert!(parse(&objs).unwrap_err().to_string().contains("nesting"));
    }

    #[test]
    fn truncated_input_rejected_at_every_prefix() {
        // ASCII document where every proper prefix is invalid.
        let doc = r#"{"a":[1,2,{"b":null}],"c":"x\ny"}"#;
        assert!(parse(doc).is_ok());
        for i in 1..doc.len() {
            assert!(parse(&doc[..i]).is_err(), "prefix of len {i} parsed");
        }
    }

    /// Random `Json` tree from the shared SplitMix64 stream. Numbers are
    /// drawn exactly representable through the shortest-roundtrip f64
    /// formatter (ints, `u64_to_f32` values, dyadic fractions), so value
    /// equality after a parse round trip is exact.
    fn random_json(state: &mut u64, depth: usize) -> Json {
        use crate::rng::{splitmix64, u64_to_f32};
        let r = splitmix64(state);
        match r % if depth == 0 { 4 } else { 6 } {
            0 => Json::Null,
            1 => Json::Bool(r & 8 == 0),
            2 => match r % 3 {
                0 => Json::Num((r >> 32) as i32 as f64),
                1 => Json::Num(u64_to_f32(splitmix64(state)) as f64),
                _ => Json::Num((splitmix64(state) % 1_000_000) as f64 / 64.0),
            },
            3 => Json::Str(random_string(state)),
            4 => Json::Arr(
                (0..splitmix64(state) % 4)
                    .map(|_| random_json(state, depth - 1))
                    .collect(),
            ),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for _ in 0..splitmix64(state) % 4 {
                    m.insert(random_string(state), random_json(state, depth - 1));
                }
                Json::Obj(m)
            }
        }
    }

    fn random_string(state: &mut u64) -> String {
        const PALETTE: &[char] = &[
            'a', 'b', 'z', '0', ' ', '"', '\\', '\n', '\t', '\r', '\u{1}', '\u{1f}', 'é', '☃',
            '/', '{', '}',
        ];
        let n = crate::rng::splitmix64(state) % 9;
        (0..n)
            .map(|_| PALETTE[(crate::rng::splitmix64(state) % PALETTE.len() as u64) as usize])
            .collect()
    }

    #[test]
    fn property_parse_inverts_emission() {
        let mut state = 0xA11CE_u64;
        for i in 0..300 {
            let v = random_json(&mut state, 4);
            let compact = v.to_string_compact();
            assert_eq!(parse(&compact).unwrap(), v, "iter {i}: {compact}");
            assert_eq!(parse(&v.to_string_pretty()).unwrap(), v, "iter {i} (pretty)");
        }
    }

    // ---- lazy scanner ----

    #[test]
    fn scan_extracts_without_full_parse() {
        let doc = r#"{"model":"resnet18","meta":{"a":[1,{"b":"}]"}]},"input":[1,-2.5,3e2]}"#;
        assert_eq!(
            scan_str_field(doc, "model").unwrap().as_deref(),
            Some("resnet18")
        );
        assert_eq!(
            scan_f32_array_field(doc, "input").unwrap().unwrap(),
            vec![1.0, -2.5, 300.0]
        );
        assert_eq!(scan_str_field(doc, "absent").unwrap(), None);
        assert_eq!(scan_f32_array_field(doc, "absent").unwrap(), None);
        assert_eq!(scan_f32_array_field(r#"{"input":[]}"#, "input").unwrap(), Some(vec![]));
    }

    #[test]
    fn scan_agrees_with_full_parse() {
        let doc = r#"{"a":"x☃y","nums":[0.5,1,2,3.25],"z":null}"#;
        let full = parse(doc).unwrap();
        assert_eq!(
            scan_str_field(doc, "a").unwrap().as_deref(),
            full.get("a").unwrap().as_str()
        );
        let lazy = scan_f32_array_field(doc, "nums").unwrap().unwrap();
        let tree: Vec<f32> = full
            .arr_field("nums")
            .unwrap()
            .iter()
            .map(|j| j.as_f64().unwrap() as f32)
            .collect();
        assert_eq!(lazy, tree);
    }

    #[test]
    fn scan_is_lazy_past_the_match() {
        // Garbage *after* the matched field goes unseen — documented
        // hot-path tradeoff.
        let doc = r#"{"input":[1,2],"junk":}"#;
        assert_eq!(
            scan_f32_array_field(doc, "input").unwrap().unwrap(),
            vec![1.0, 2.0]
        );
        // …but garbage before the match still errors.
        assert!(scan_f32_array_field(r#"{"junk":,"input":[1]}"#, "input").is_err());
    }

    #[test]
    fn scan_type_and_shape_errors() {
        assert!(scan_str_field(r#"{"model":42}"#, "model").is_err());
        assert!(scan_f32_array_field(r#"{"input":"no"}"#, "input").is_err());
        assert!(scan_f32_array_field(r#"{"input":[1,[2]]}"#, "input").is_err());
        assert!(scan_f32_array_field(r#"{"input":[1,2"#, "input").is_err());
        assert!(scan_str_field("[1,2]", "model").is_err(), "top level must be an object");
    }

    #[test]
    fn scan_skip_honours_depth_limit() {
        // A nesting bomb in a *skipped* field must not recurse away.
        let bomb = format!(
            r#"{{"pad":{}0{},"input":[1]}}"#,
            "[".repeat(MAX_DEPTH + 50),
            "]".repeat(MAX_DEPTH + 50)
        );
        let err = scan_f32_array_field(&bomb, "input").unwrap_err().to_string();
        assert!(err.contains("nesting"), "{err}");
    }
}
