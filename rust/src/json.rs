//! Minimal JSON substrate (parser + writer).
//!
//! The build environment is offline without serde, so the artifact
//! manifest, graph interop with the python compile path, and report
//! emission use this small, strict JSON implementation instead. It
//! supports the full JSON grammar except `\u` surrogate pairs beyond the
//! BMP are passed through unvalidated.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects use a BTreeMap so emission is deterministic
/// (stable key order), which the golden-file tests rely on.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- constructors ----

    pub fn object() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_usize(v: usize) -> Json {
        Json::Num(v as f64)
    }

    /// Insert into an object (panics if not an object).
    pub fn set(&mut self, key: &str, value: Json) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value);
            }
            _ => panic!("set() on non-object"),
        }
        self
    }

    // ---- accessors ----

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Field access that errors with the key name (for manifest parsing).
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing field '{key}'"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Typed helpers for manifest decoding.
    pub fn str_field(&self, key: &str) -> anyhow::Result<String> {
        Ok(self
            .req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' not a string"))?
            .to_string())
    }

    pub fn usize_field(&self, key: &str) -> anyhow::Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' not a non-negative integer"))
    }

    pub fn f64_field(&self, key: &str) -> anyhow::Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' not a number"))
    }

    pub fn bool_field(&self, key: &str) -> anyhow::Result<bool> {
        self.req(key)?
            .as_bool()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' not a bool"))
    }

    pub fn arr_field(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' not an array"))
    }

    /// Decode `[1,2,3]` into a usize vector.
    pub fn usize_vec(&self) -> anyhow::Result<Vec<usize>> {
        self.as_arr()
            .ok_or_else(|| anyhow::anyhow!("not an array"))?
            .iter()
            .map(|j| j.as_usize().ok_or_else(|| anyhow::anyhow!("not a usize")))
            .collect()
    }

    // ---- emission ----

    /// Compact single-line rendering.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty rendering with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, level: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    item.write(out, indent, level + 1);
                }
                newline_indent(out, indent, level);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, level + 1);
                }
                newline_indent(out, indent, level);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, level: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * level {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Returns an error with byte position context.
pub fn parse(input: &str) -> anyhow::Result<Json> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        anyhow::bail!("trailing garbage at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> anyhow::Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of input at byte {}", self.pos))
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        if self.peek()? == b {
            self.pos += 1;
            Ok(())
        } else {
            anyhow::bail!(
                "expected '{}' at byte {}, found '{}'",
                b as char,
                self.pos,
                self.peek()? as char
            )
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> anyhow::Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            anyhow::bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => anyhow::bail!("unexpected '{}' at byte {}", c as char, self.pos),
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                c => anyhow::bail!("expected ',' or '}}' at byte {}, got '{}'", self.pos, c as char),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                c => anyhow::bail!("expected ',' or ']' at byte {}, got '{}'", self.pos, c as char),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                anyhow::bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        c => anyhow::bail!("bad escape '\\{}'", c as char),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                _ => {
                    // Multi-byte UTF-8: re-decode from the byte slice.
                    let start = self.pos - 1;
                    let slice = &self.bytes[start..];
                    let ch = std::str::from_utf8(&slice[..slice.len().min(4)])
                        .ok()
                        .and_then(|t| t.chars().next())
                        .ok_or_else(|| anyhow::anyhow!("invalid utf-8 at byte {start}"))?;
                    s.push(ch);
                    self.pos = start + ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        if self.peek()? == b'-' {
            self.pos += 1;
        }
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| {
            anyhow::anyhow!("bad number '{text}' at byte {start}: {e}")
        })?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-12", "3.5", "\"hi\""] {
            let v = parse(src).unwrap();
            assert_eq!(parse(&v.to_string_compact()).unwrap(), v, "{src}");
        }
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a":[1,2,{"b":null}],"c":"x\ny","d":{"e":[]},"f":1e3}"#;
        let v = parse(src).unwrap();
        let emitted = v.to_string_compact();
        assert_eq!(parse(&emitted).unwrap(), v);
        assert_eq!(v.get("f").unwrap().as_f64().unwrap(), 1000.0);
    }

    #[test]
    fn object_builder_and_accessors() {
        let mut o = Json::object();
        o.set("n", Json::from_usize(42))
            .set("s", Json::Str("x".into()))
            .set("a", Json::Arr(vec![Json::from_usize(1), Json::from_usize(2)]));
        assert_eq!(o.usize_field("n").unwrap(), 42);
        assert_eq!(o.str_field("s").unwrap(), "x");
        assert_eq!(o.arr_field("a").unwrap().len(), 2);
        assert_eq!(
            o.get("a").unwrap().usize_vec().unwrap(),
            vec![1, 2]
        );
        assert!(o.usize_field("missing").is_err());
    }

    #[test]
    fn deterministic_key_order() {
        let v = parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        assert_eq!(v.to_string_compact(), r#"{"a":2,"m":3,"z":1}"#);
    }

    #[test]
    fn escapes() {
        let v = Json::Str("a\"b\\c\nd\u{1}".into());
        let s = v.to_string_compact();
        assert_eq!(s, "\"a\\\"b\\\\c\\nd\\u0001\"");
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ☃");
        let u = parse("\"\\u2603\"").unwrap();
        assert_eq!(u.as_str().unwrap(), "☃");
    }

    #[test]
    fn errors() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("01a").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("{} extra").is_err());
    }

    #[test]
    fn pretty_is_parseable() {
        let v = parse(r#"{"a":[1,2],"b":{"c":true}}"#).unwrap();
        assert_eq!(parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn large_integers_exact() {
        let v = parse("123456789012345").unwrap();
        assert_eq!(v.as_usize().unwrap(), 123456789012345);
        assert_eq!(v.to_string_compact(), "123456789012345");
    }
}
