//! Mapping from graph layers to the collapser's basic computational
//! operations (§4.1 "Collapse Process", step 2 of Listing 1).
//!
//! Optimizable layers map 1:1 onto operations here: element-wise layers
//! (batch-norm, ReLU, dropout) become [`OpKind`] element-wise ops, pooling
//! layers become window ops. Inference-mode batch normalization is a
//! per-channel affine transform, so it is represented (and code-generated)
//! as `y = x * scale[c] + shift[c]` with `scale`/`shift` precomputed from
//! (gamma, beta, mean, var) — the same folding the paper's code generator
//! performs.

use crate::graph::{Layer, NodeId, PoolKind, Shape, Window2d};

/// The computational kind of one collapsed operation.
#[derive(Debug, Clone, PartialEq)]
pub enum OpKind {
    /// Per-channel affine (folded inference batch-norm).
    BnAffine { eps: f32 },
    /// max(x, 0).
    Relu,
    /// Identity (inference-mode dropout). Kept so layer accounting and
    /// signatures match the network structure.
    Identity,
    /// 2-D window reduction.
    Pool {
        kind: PoolKind,
        window: Window2d,
        ceil_mode: bool,
        count_include_pad: bool,
    },
}

impl OpKind {
    pub fn is_elementwise(&self) -> bool {
        !matches!(self, OpKind::Pool { .. })
    }

    /// Stable signature fragment (must match python/compile/stacks.py).
    pub fn sig(&self) -> String {
        match self {
            OpKind::BnAffine { .. } => "bn".into(),
            OpKind::Relu => "relu".into(),
            OpKind::Identity => "id".into(),
            OpKind::Pool {
                kind,
                window,
                ceil_mode,
                count_include_pad,
            } => {
                let k = match kind {
                    PoolKind::Max => "max",
                    PoolKind::Avg => "avg",
                };
                let mut s = format!("{}pool_{}", k, window.sig());
                if *ceil_mode {
                    s.push_str("_ceil");
                }
                if matches!(kind, PoolKind::Avg) && !*count_include_pad {
                    s.push_str("_nip");
                }
                s
            }
        }
    }

    /// Bytes of per-channel parameters this op keeps resident per channel
    /// (folded BN: scale + shift).
    pub fn param_bytes_per_channel(&self) -> usize {
        match self {
            OpKind::BnAffine { .. } => 2 * 4,
            _ => 0,
        }
    }
}

/// One operation inside a stack, tied back to its originating graph node.
#[derive(Debug, Clone)]
pub struct Operation {
    pub node: NodeId,
    pub name: String,
    pub kind: OpKind,
    pub in_shape: Shape,
    pub out_shape: Shape,
}

impl Operation {
    /// Build the operation for an optimizable layer; `None` otherwise.
    pub fn from_layer(node: NodeId, name: &str, layer: &Layer, in_shape: &Shape, out_shape: &Shape) -> Option<Operation> {
        let kind = match layer {
            Layer::BatchNorm2d { eps } => OpKind::BnAffine { eps: *eps },
            Layer::Relu => OpKind::Relu,
            Layer::Dropout { .. } => OpKind::Identity,
            Layer::Pool2d {
                kind,
                window,
                ceil_mode,
                count_include_pad,
            } => OpKind::Pool {
                kind: *kind,
                window: *window,
                ceil_mode: *ceil_mode,
                count_include_pad: *count_include_pad,
            },
            _ => return None,
        };
        Some(Operation {
            node,
            name: name.to_string(),
            kind,
            in_shape: in_shape.clone(),
            out_shape: out_shape.clone(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elementwise_classification() {
        assert!(OpKind::Relu.is_elementwise());
        assert!(OpKind::BnAffine { eps: 1e-5 }.is_elementwise());
        assert!(OpKind::Identity.is_elementwise());
        let pool = OpKind::Pool {
            kind: PoolKind::Max,
            window: Window2d::square(3, 1, 1),
            ceil_mode: false,
            count_include_pad: true,
        };
        assert!(!pool.is_elementwise());
    }

    #[test]
    fn signatures() {
        assert_eq!(OpKind::Relu.sig(), "relu");
        assert_eq!(OpKind::BnAffine { eps: 1e-3 }.sig(), "bn");
        let pool = OpKind::Pool {
            kind: PoolKind::Avg,
            window: Window2d::square(2, 2, 0),
            ceil_mode: false,
            count_include_pad: false,
        };
        assert_eq!(pool.sig(), "avgpool_k2x2s2x2p0x0_nip");
        let mp = OpKind::Pool {
            kind: PoolKind::Max,
            window: Window2d::square(3, 2, 0),
            ceil_mode: true,
            count_include_pad: true,
        };
        assert_eq!(mp.sig(), "maxpool_k3x3s2x2p0x0_ceil");
    }

    #[test]
    fn from_layer_filters_nonoptimizable() {
        let s = Shape::nchw(1, 4, 8, 8);
        assert!(Operation::from_layer(1, "relu", &Layer::Relu, &s, &s).is_some());
        assert!(Operation::from_layer(
            1,
            "conv",
            &Layer::Conv2d {
                out_channels: 4,
                window: Window2d::square(3, 1, 1),
                bias: false
            },
            &s,
            &s
        )
        .is_none());
        assert!(Operation::from_layer(1, "add", &Layer::Add, &s, &s).is_none());
    }
}
