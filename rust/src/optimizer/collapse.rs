//! The collapse process (§4.1, Figure 9, Listing 1): group a stack's
//! operations into *steps* (at most one non-element-wise op per step) and
//! pack steps into *sequences* whose depth-first working set fits the
//! device's fast-memory budget.
//!
//! ## Tiling model
//!
//! Depth-first execution processes one *band* of `tile_rows` output rows
//! (full width, one (batch, channel) plane) through all steps of a
//! sequence before touching the next band — the Pallas kernel's grid is
//! `(batch·channels, n_bands)`. Working backwards through the steps, a
//! band of `r` output rows at step `i` needs `(r-1)·stride_h + kernel_h`
//! input rows, so earlier steps hold progressively taller bands (the halo
//! growth that produces Figure 10's spill artifacts). The working set of
//! a sequence is the largest adjacent in+out band pair (two VMEM/cache
//! buffers, ping-pong per §4.4), plus resident per-channel parameters.

use crate::device::DeviceSpec;
use crate::graph::Shape;

use super::ops::{OpKind, Operation};

/// Band geometry of a tensor: (rows, elements per row). Rank-4 NCHW
/// tensors band over H within one (batch, channel) plane; rank-2 (N, F)
/// tensors band over the batch dimension (pure element-wise stacks in
/// classifier heads).
fn row_geometry(shape: &Shape) -> (usize, usize) {
    match shape.rank() {
        4 => (shape.height(), shape.width()),
        2 => (shape.batch(), shape.channels()),
        r => panic!("unsupported rank {r} in collapse"),
    }
}

/// A step: a run of element-wise ops with at most one pooling op.
#[derive(Debug, Clone)]
pub struct Step {
    pub ops: Vec<Operation>,
}

impl Step {
    pub fn new() -> Self {
        Step { ops: Vec::new() }
    }

    /// Listing 1's `onlyElementwise()`.
    pub fn only_elementwise(&self) -> bool {
        self.ops.iter().all(|o| o.kind.is_elementwise())
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// The pooling op of this step, if any.
    pub fn pool(&self) -> Option<&Operation> {
        self.ops.iter().find(|o| !o.kind.is_elementwise())
    }

    /// Shape entering / leaving the step (full-tensor).
    pub fn in_shape(&self) -> &Shape {
        &self.ops.first().expect("empty step").in_shape
    }

    pub fn out_shape(&self) -> &Shape {
        &self.ops.last().expect("empty step").out_shape
    }

    /// (kernel_h, stride_h) of the step's spatial reduction (1,1 if pure
    /// element-wise). Used for band back-propagation.
    pub fn row_window(&self) -> (usize, usize) {
        match self.pool().map(|p| &p.kind) {
            Some(OpKind::Pool { window, .. }) => (window.kernel.0, window.stride.0),
            _ => (1, 1),
        }
    }

    /// Input rows required to produce `rows` output rows. A zero-row
    /// band needs zero input rows (guards the `rows - 1` underflow that
    /// `CollapseOptions { min_tile_rows: 0, .. }` used to reach).
    pub fn in_rows(&self, rows: usize) -> usize {
        if rows == 0 {
            return 0;
        }
        let (k, s) = self.row_window();
        (rows - 1) * s + k
    }

    /// Per-channel parameter bytes resident while this step runs.
    pub fn param_bytes_per_channel(&self) -> usize {
        self.ops
            .iter()
            .map(|o| o.kind.param_bytes_per_channel())
            .sum()
    }

    pub fn sig(&self) -> String {
        self.ops
            .iter()
            .map(|o| o.kind.sig())
            .collect::<Vec<_>>()
            .join(",")
    }
}

impl Default for Step {
    fn default() -> Self {
        Self::new()
    }
}

/// A sequence: consecutive steps whose depth-first working set fits the
/// device budget. Sequence boundaries synchronize through main memory.
#[derive(Debug, Clone)]
pub struct Sequence {
    pub steps: Vec<Step>,
    /// Output rows per depth-first band (chosen by [`collapse`]).
    pub tile_rows: usize,
}

impl Sequence {
    pub fn in_shape(&self) -> &Shape {
        self.steps.first().expect("empty sequence").in_shape()
    }

    pub fn out_shape(&self) -> &Shape {
        self.steps.last().expect("empty sequence").out_shape()
    }

    /// Input rows of the *first* step needed for one band of `rows`
    /// final-output rows — the halo-grown extent. Each step's band is
    /// clamped to its actual input height: padded windows (k3 s1 p1)
    /// produce out rows without extra input rows, so the naive
    /// `(r-1)·s + k` back-propagation would demand more rows than the
    /// tensor has.
    pub fn in_rows_for(&self, rows: usize) -> usize {
        let mut r = rows;
        for step in self.steps.iter().rev() {
            let (in_h, _) = row_geometry(step.in_shape());
            r = step.in_rows(r).min(in_h);
        }
        r
    }

    /// Working-set bytes for a band of `rows` output rows: the largest
    /// (input band + output band) pair across steps, plus resident
    /// per-channel params. Matches the two-buffer ping-pong execution.
    pub fn working_set_bytes(&self, rows: usize) -> usize {
        // Band heights entering each step (and leaving the last), each
        // clamped to the tensor it actually reads — see `in_rows_for`.
        let mut heights = Vec::with_capacity(self.steps.len() + 1);
        let (out_h, _) = row_geometry(self.out_shape());
        let mut r = rows.min(out_h);
        heights.push(r);
        for step in self.steps.iter().rev() {
            let (in_h, _) = row_geometry(step.in_shape());
            r = step.in_rows(r).min(in_h);
            heights.push(r);
        }
        heights.reverse(); // heights[i] = rows entering step i; last = out
        let mut worst = 0usize;
        let mut params = 0usize;
        for (i, step) in self.steps.iter().enumerate() {
            let in_shape = step.in_shape();
            let out_shape = step.out_shape();
            let (_, in_row_elems) = row_geometry(in_shape);
            let (_, out_row_elems) = row_geometry(out_shape);
            let in_bytes = heights[i] * in_row_elems * in_shape.dtype.bytes();
            let out_bytes = heights[i + 1] * out_row_elems * out_shape.dtype.bytes();
            worst = worst.max(in_bytes + out_bytes);
            params += step.param_bytes_per_channel();
        }
        worst + params
    }

    /// Total steps' ops count.
    pub fn num_ops(&self) -> usize {
        self.steps.iter().map(|s| s.ops.len()).sum()
    }

    pub fn sig(&self) -> String {
        self.steps
            .iter()
            .map(|s| s.sig())
            .collect::<Vec<_>>()
            .join(";")
    }

    /// Redundancy factor of the halo: input rows actually read across
    /// all bands divided by the rows a non-overlapping decomposition
    /// would read. 1.0 = no redundancy. Drives the memsim traffic model.
    ///
    /// The final band is usually *partial* (`out_h % tile_rows` rows),
    /// so its halo-grown input extent is computed from its actual
    /// height; treating every band as full-height (`n_bands ×
    /// in_rows_for(tile_rows)`) over-estimates read traffic whenever the
    /// tile does not divide the output height.
    pub fn halo_overlap_factor(&self) -> f64 {
        let (out_h, _) = row_geometry(self.out_shape());
        let rows = self.tile_rows.min(out_h).max(1);
        let n_bands = out_h.div_ceil(rows);
        let full_bands = n_bands - 1;
        let last_rows = out_h - full_bands * rows;
        let read_rows =
            (full_bands * self.in_rows_for(rows) + self.in_rows_for(last_rows)) as f64;
        let (in_h, _) = row_geometry(self.in_shape());
        (read_rows / in_h as f64).max(1.0)
    }
}

/// Collapse strategy: Figure 10 evaluates 1-step, 5-step and unrestricted
/// sequences.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CollapseOptions {
    /// Maximum steps per sequence (`None` = unrestricted).
    pub max_steps_per_sequence: Option<usize>,
    /// Minimum output rows per band (keep SIMD lanes busy).
    pub min_tile_rows: usize,
    /// Fast-memory bytes pinned by concurrently-live buffers while the
    /// collapsed kernels run — the branch-aware planner reserves the
    /// skip-connection plane held across a branch arm here. Packing and
    /// band-height decisions use `resource_limit() - reserved_bytes`,
    /// floored at 1/8 of the device limit (past that the live buffer is
    /// assumed spilled to main memory instead of strangling the bands).
    pub reserved_bytes: usize,
    /// Working-set budget override in bytes: when set, packing and
    /// band-height decisions use this instead of
    /// `device.resource_limit()`. This is the autotuner's budget-scale
    /// knob — the device presets derive budgets from static cache
    /// parameters, but the empirically best budget varies per network
    /// and machine. The `reserved_bytes` floor (1/8) is taken against
    /// the injected budget. `None` = use the device preset.
    pub budget_bytes: Option<usize>,
    /// Upper bound on the chosen band height (`None` = unrestricted).
    /// Wins over `min_tile_rows` when the two conflict. The autotuner
    /// sweeps this cap; the parity suite pins the degenerate
    /// `Some(1)` (single-row bands) and huge-`min_tile_rows`
    /// (whole-plane bands) corners to the breadth-first baseline.
    pub max_tile_rows: Option<usize>,
}

impl Default for CollapseOptions {
    fn default() -> Self {
        CollapseOptions {
            max_steps_per_sequence: None,
            min_tile_rows: 1,
            reserved_bytes: 0,
            budget_bytes: None,
            max_tile_rows: None,
        }
    }
}

/// Does a reservation of `reserved_bytes` actually hold on `device` —
/// i.e. is the effective budget *not* floored? When this is false the
/// collapse budget bottoms out at `resource_limit() / 8` and the live
/// buffer is assumed spilled to main memory (its consumers pay a
/// re-read there instead). The memsim join model applies the same
/// predicate when deciding whether a skip read hits the fast tier.
pub fn reservation_holds(device: &DeviceSpec, reserved_bytes: usize) -> bool {
    let limit = device.resource_limit();
    limit.saturating_sub(reserved_bytes) >= limit / 8
}

/// Working-set budget after the reservation policy documented on
/// [`CollapseOptions::reserved_bytes`], starting from the injected
/// [`CollapseOptions::budget_bytes`] when one is set (the autotuner's
/// budget-scale knob) and the device preset otherwise.
///
/// Public so the static plan verifier
/// (`crate::analysis::verify_resources`) re-derives the *same* budget
/// the packer used instead of approximating it.
pub fn effective_budget(device: &DeviceSpec, opts: &CollapseOptions) -> usize {
    let limit = opts.budget_bytes.unwrap_or(device.resource_limit());
    limit
        .saturating_sub(opts.reserved_bytes)
        .max(limit / 8)
        .max(1)
}

/// Band-height cap from [`CollapseOptions::max_tile_rows`] (≥ 1).
fn tile_cap(opts: &CollapseOptions) -> usize {
    opts.max_tile_rows.unwrap_or(usize::MAX).max(1)
}

/// Listing 1 steps #3 and #4: group operations into steps, then pack
/// steps into sequences against `device.resource_limit()`, choosing each
/// sequence's band height.
pub fn collapse(ops: &[Operation], device: &DeviceSpec, opts: &CollapseOptions) -> Vec<Sequence> {
    assert!(!ops.is_empty(), "collapse() on empty op list");

    // #3: group operations in steps — an op joins the current step unless
    // it is non-element-wise and the step already has one.
    let mut steps: Vec<Step> = Vec::new();
    let mut step = Step::new();
    for op in ops {
        if !op.kind.is_elementwise() && !step.only_elementwise() {
            steps.push(step);
            step = Step::new();
        }
        step.ops.push(op.clone());
    }
    if !step.is_empty() {
        steps.push(step);
    }

    // #4: group steps in sequences subject to the working-set budget.
    // A band is at least one row tall; `min_tile_rows: 0` is clamped
    // rather than fed into the band back-propagation.
    let min_rows = opts.min_tile_rows.max(1).min(tile_cap(opts));
    let budget = effective_budget(device, opts);
    let mut sequences: Vec<Sequence> = Vec::new();
    let mut current: Vec<Step> = Vec::new();
    for st in steps {
        current.push(st);
        let over_len = opts
            .max_steps_per_sequence
            .is_some_and(|m| current.len() > m);
        let probe = Sequence {
            steps: current.clone(),
            tile_rows: min_rows,
        };
        let over_mem = probe.working_set_bytes(min_rows) > budget;
        if (over_len || over_mem) && current.len() > 1 {
            // len > 1 was just checked, so the pop always yields a step.
            if let Some(st) = current.pop() {
                sequences.push(seal(current, device, opts));
                current = vec![st];
            }
        }
    }
    if !current.is_empty() {
        sequences.push(seal(current, device, opts));
    }
    sequences
}

/// Finalize a sequence: grow the band height while the working set fits
/// (§4.1: "in the case that the cache size limit is not reached, we
/// increase [the tile] so that each SIMD unit may calculate multiple
/// output values").
fn seal(steps: Vec<Step>, device: &DeviceSpec, opts: &CollapseOptions) -> Sequence {
    let (out_h, _) = row_geometry(steps.last().expect("empty sequence").out_shape());
    let budget = effective_budget(device, opts);
    let max_rows = tile_cap(opts);
    let min_rows = opts.min_tile_rows.max(1).min(max_rows);
    let mut seq = Sequence {
        steps,
        tile_rows: min_rows,
    };
    let mut rows = min_rows.min(out_h.max(1));
    while rows < out_h && rows < max_rows && seq.working_set_bytes(rows + 1) <= budget {
        rows += 1;
    }
    seq.tile_rows = rows;
    seq
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Layer, PoolKind, Window2d};

    fn mk_ops(spec: &[(&str, usize)], c: usize, h: usize) -> Vec<Operation> {
        // spec: sequence of ("bn"|"relu"|"id"|"max3s1p1"|"max2s2") ops.
        let mut ops = Vec::new();
        let mut shape = Shape::nchw(1, c, h, h);
        for (i, (kind, _)) in spec.iter().enumerate() {
            let layer = match *kind {
                "bn" => Layer::BatchNorm2d { eps: 1e-5 },
                "relu" => Layer::Relu,
                "id" => Layer::Dropout { p: 0.5 },
                "max3s1p1" => Layer::Pool2d {
                    kind: PoolKind::Max,
                    window: Window2d::square(3, 1, 1),
                    ceil_mode: false,
                    count_include_pad: true,
                },
                "max2s2" => Layer::Pool2d {
                    kind: PoolKind::Max,
                    window: Window2d::square(2, 2, 0),
                    ceil_mode: false,
                    count_include_pad: true,
                },
                other => panic!("unknown {other}"),
            };
            let out = layer.infer_shape(&[&shape]).unwrap();
            ops.push(
                Operation::from_layer(i + 1, &format!("op{i}"), &layer, &shape, &out).unwrap(),
            );
            shape = out;
        }
        ops
    }

    fn dev(budget: usize) -> DeviceSpec {
        DeviceSpec {
            fast_mem_bytes: budget,
            ..DeviceSpec::paper_gpu()
        }
    }

    #[test]
    fn step_grouping_one_pool_per_step() {
        // Element-wise ops always join the current step; a pooling op
        // joins only if the step has none yet (Listing 1 #3). So
        // bn,relu,max,bn,relu,max groups as [bn,relu,max,bn,relu],[max].
        let ops = mk_ops(
            &[
                ("bn", 0),
                ("relu", 0),
                ("max3s1p1", 0),
                ("bn", 0),
                ("relu", 0),
                ("max3s1p1", 0),
            ],
            8,
            32,
        );
        let seqs = collapse(&ops, &dev(1 << 20), &CollapseOptions::default());
        let steps: Vec<&Step> = seqs.iter().flat_map(|s| &s.steps).collect();
        assert_eq!(steps.len(), 2);
        assert_eq!(steps[0].sig(), "bn,relu,maxpool_k3x3s1x1p1x1,bn,relu");
        assert_eq!(steps[1].sig(), "maxpool_k3x3s1x1p1x1");
        // Fig 10's block order <MaxPool,BN,ReLU> groups one block per step.
        let ops = mk_ops(
            &[
                ("max3s1p1", 0),
                ("bn", 0),
                ("relu", 0),
                ("max3s1p1", 0),
                ("bn", 0),
                ("relu", 0),
            ],
            8,
            32,
        );
        let seqs = collapse(&ops, &dev(1 << 20), &CollapseOptions::default());
        let steps: Vec<&Step> = seqs.iter().flat_map(|s| &s.steps).collect();
        assert_eq!(steps.len(), 2);
        for s in steps {
            assert_eq!(s.sig(), "maxpool_k3x3s1x1p1x1,bn,relu");
        }
    }

    #[test]
    fn trailing_elementwise_joins_pool_step() {
        let ops = mk_ops(&[("max3s1p1", 0), ("bn", 0), ("relu", 0)], 8, 32);
        let seqs = collapse(&ops, &dev(1 << 20), &CollapseOptions::default());
        assert_eq!(seqs.len(), 1);
        assert_eq!(seqs[0].steps.len(), 1);
        assert_eq!(seqs[0].steps[0].sig(), "maxpool_k3x3s1x1p1x1,bn,relu");
    }

    #[test]
    fn band_backprop_through_strided_pool() {
        let ops = mk_ops(&[("max2s2", 0), ("max2s2", 0)], 4, 32);
        let seqs = collapse(&ops, &dev(1 << 20), &CollapseOptions::default());
        let seq = &seqs[0];
        // 1 output row needs 2 rows mid, 4 rows input.
        assert_eq!(seq.in_rows_for(1), 4);
        assert_eq!(seq.in_rows_for(2), 8);
    }

    #[test]
    fn halo_growth_with_stacked_same_pools() {
        // k3 s1 p1 pools: each step adds 2 rows of halo.
        let ops = mk_ops(
            &[("max3s1p1", 0), ("max3s1p1", 0), ("max3s1p1", 0)],
            4,
            32,
        );
        let seqs = collapse(&ops, &dev(1 << 20), &CollapseOptions::default());
        assert_eq!(seqs.len(), 1);
        assert_eq!(seqs[0].in_rows_for(1), 7); // 1 + 2*3
    }

    #[test]
    fn memory_budget_splits_sequences() {
        // Huge images + tiny budget force per-step sequences.
        let ops = mk_ops(
            &[
                ("max3s1p1", 0),
                ("max3s1p1", 0),
                ("max3s1p1", 0),
                ("max3s1p1", 0),
            ],
            32,
            224,
        );
        let tiny = dev(4 * 1024);
        let unrestricted = collapse(&ops, &tiny, &CollapseOptions::default());
        assert!(unrestricted.len() > 1, "tiny budget must split");
        let big = dev(64 * 1024 * 1024);
        let merged = collapse(&ops, &big, &CollapseOptions::default());
        assert_eq!(merged.len(), 1, "huge budget keeps one sequence");
    }

    #[test]
    fn max_steps_strategy() {
        let ops = mk_ops(
            &[
                ("max3s1p1", 0),
                ("max3s1p1", 0),
                ("max3s1p1", 0),
                ("max3s1p1", 0),
                ("max3s1p1", 0),
            ],
            8,
            32,
        );
        let one = collapse(
            &ops,
            &dev(1 << 24),
            &CollapseOptions {
                max_steps_per_sequence: Some(1),
                ..Default::default()
            },
        );
        assert_eq!(one.len(), 5);
        let two = collapse(
            &ops,
            &dev(1 << 24),
            &CollapseOptions {
                max_steps_per_sequence: Some(2),
                ..Default::default()
            },
        );
        assert_eq!(two.len(), 3);
    }

    #[test]
    fn ops_partition_exactly_across_sequences() {
        let ops = mk_ops(
            &[
                ("bn", 0),
                ("relu", 0),
                ("max3s1p1", 0),
                ("bn", 0),
                ("max2s2", 0),
                ("relu", 0),
            ],
            16,
            64,
        );
        for budget in [2 * 1024, 16 * 1024, 1 << 22] {
            let seqs = collapse(&ops, &dev(budget), &CollapseOptions::default());
            let flat: Vec<&Operation> = seqs
                .iter()
                .flat_map(|s| &s.steps)
                .flat_map(|st| &st.ops)
                .collect();
            assert_eq!(flat.len(), ops.len(), "budget {budget}");
            for (a, b) in flat.iter().zip(ops.iter()) {
                assert_eq!(a.node, b.node, "budget {budget}");
            }
            // Shapes chain across sequence boundaries.
            for w in seqs.windows(2) {
                assert_eq!(w[0].out_shape(), w[1].in_shape());
            }
        }
    }

    #[test]
    fn tile_rows_grow_with_budget() {
        let ops = mk_ops(&[("bn", 0), ("relu", 0)], 8, 64);
        let small = collapse(&ops, &dev(2 * 1024), &CollapseOptions::default());
        let large = collapse(&ops, &dev(64 * 1024), &CollapseOptions::default());
        assert!(large[0].tile_rows >= small[0].tile_rows);
        // And the chosen tile respects the budget.
        for s in [&small[0], &large[0]] {
            assert!(s.working_set_bytes(s.tile_rows) <= 64 * 1024);
        }
    }

    #[test]
    fn halo_overlap_factor_increases_with_depth() {
        let shallow = collapse(
            &mk_ops(&[("max3s1p1", 0)], 4, 64),
            &dev(4 * 1024),
            &CollapseOptions::default(),
        );
        let deep = collapse(
            &mk_ops(
                &[
                    ("max3s1p1", 0),
                    ("max3s1p1", 0),
                    ("max3s1p1", 0),
                    ("max3s1p1", 0),
                    ("max3s1p1", 0),
                    ("max3s1p1", 0),
                ],
                4,
                64,
            ),
            &dev(4 * 1024),
            &CollapseOptions::default(),
        );
        // Deep single sequence (if it fits) must have a worse halo factor
        // than the shallow one.
        if deep.len() == 1 {
            assert!(deep[0].halo_overlap_factor() >= shallow[0].halo_overlap_factor());
        }
    }

    #[test]
    fn zero_min_tile_rows_is_clamped_not_underflowed() {
        // `rows - 1` on usize used to underflow (panic in debug builds)
        // when CollapseOptions asked for zero-row bands.
        let ops = mk_ops(&[("max3s1p1", 0), ("bn", 0), ("relu", 0)], 4, 16);
        let opts = CollapseOptions {
            min_tile_rows: 0,
            ..Default::default()
        };
        let seqs = collapse(&ops, &dev(1 << 20), &opts);
        assert!(!seqs.is_empty());
        for s in &seqs {
            assert!(s.tile_rows >= 1, "bands are at least one row tall");
            assert!(s.working_set_bytes(s.tile_rows) > 0);
        }
        // in_rows itself is total: zero output rows need zero input rows.
        assert_eq!(seqs[0].steps[0].in_rows(0), 0);
    }

    #[test]
    fn halo_clamped_to_input_height() {
        // Three k3 s1 p1 pools over an 8-row input: padding supplies the
        // window edges, so a full 8-row output band needs exactly the 8
        // input rows the tensor has — not 8 + 2·steps = 14.
        let ops = mk_ops(
            &[("max3s1p1", 0), ("max3s1p1", 0), ("max3s1p1", 0)],
            4,
            8,
        );
        let seqs = collapse(&ops, &dev(1 << 20), &CollapseOptions::default());
        assert_eq!(seqs.len(), 1);
        let seq = &seqs[0];
        assert_eq!(seq.in_rows_for(8), 8);
        // Working set of the full-tensor band is two 8-row planes plus
        // resident params — never more than the tensors occupy.
        let plane = 8 * 8 * seq.in_shape().dtype.bytes();
        let params: usize = seq
            .steps
            .iter()
            .map(|s| s.param_bytes_per_channel())
            .sum();
        assert_eq!(seq.working_set_bytes(8), 2 * plane + params);
        // Small bands still grow their halo normally (1 → 3 → 5 → 7).
        assert_eq!(seq.in_rows_for(1), 7);
    }

    #[test]
    fn halo_factor_sums_partial_final_band() {
        // One k3 s1 p1 pool over a 10-row plane, banded at 4 output
        // rows: bands of 4, 4, 2 read 6 + 6 + 4 = 16 input rows.
        // The old `n_bands * in_rows_for(tile)` formula claimed
        // 3 * 6 = 18 (factor 1.8) — over-estimating DF read traffic on
        // every non-divisible height.
        let ops = mk_ops(&[("max3s1p1", 0)], 2, 10);
        let mut seq = collapse(&ops, &dev(1 << 20), &CollapseOptions::default())
            .pop()
            .unwrap();
        seq.tile_rows = 4;
        assert_eq!(seq.in_rows_for(4), 6);
        assert_eq!(seq.in_rows_for(2), 4);
        let factor = seq.halo_overlap_factor();
        assert!((factor - 1.6).abs() < 1e-12, "got {factor}");
        // Divisible heights are unchanged: 10 = 2 * 5 bands of 2 rows
        // read 4 rows each -> 20/10 = 2.0 under both formulas.
        seq.tile_rows = 2;
        assert!((seq.halo_overlap_factor() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn reserved_bytes_shrink_band_height() {
        // An element-wise stack on a 64-row plane: reserving most of the
        // budget for a live skip buffer must reduce the chosen band.
        let ops = mk_ops(&[("bn", 0), ("relu", 0)], 8, 64);
        let device = dev(16 * 1024);
        let free = collapse(&ops, &device, &CollapseOptions::default());
        let reserved = collapse(
            &ops,
            &device,
            &CollapseOptions {
                reserved_bytes: 12 * 1024,
                ..Default::default()
            },
        );
        assert!(reserved[0].tile_rows < free[0].tile_rows);
        assert!(reserved[0].working_set_bytes(reserved[0].tile_rows) <= 4 * 1024);
        assert!(reservation_holds(&device, 12 * 1024));
        assert!(!reservation_holds(&device, 1 << 30));
        // Reserving more than the whole budget floors at limit/8 rather
        // than underflowing to a zero-byte budget.
        let floored = collapse(
            &ops,
            &device,
            &CollapseOptions {
                reserved_bytes: 1 << 30,
                ..Default::default()
            },
        );
        assert!(floored[0].tile_rows >= 1);
        assert!(floored[0].working_set_bytes(floored[0].tile_rows) <= 16 * 1024 / 8);
    }

    #[test]
    fn budget_override_replaces_device_limit() {
        // Same op list, same device: a tiny injected budget must split
        // where the device budget would merge, and a huge injected
        // budget must merge where the device budget would split.
        let ops = mk_ops(
            &[
                ("max3s1p1", 0),
                ("max3s1p1", 0),
                ("max3s1p1", 0),
                ("max3s1p1", 0),
            ],
            32,
            224,
        );
        let big_dev = dev(64 * 1024 * 1024);
        let split = collapse(
            &ops,
            &big_dev,
            &CollapseOptions {
                budget_bytes: Some(4 * 1024),
                ..Default::default()
            },
        );
        assert!(split.len() > 1, "tiny injected budget must split");
        let tiny_dev = dev(4 * 1024);
        let merged = collapse(
            &ops,
            &tiny_dev,
            &CollapseOptions {
                budget_bytes: Some(64 * 1024 * 1024),
                ..Default::default()
            },
        );
        assert_eq!(merged.len(), 1, "huge injected budget keeps one sequence");
        // Chosen tiles respect the *injected* budget, not the device's.
        for s in &merged {
            assert!(s.working_set_bytes(s.tile_rows) <= 64 * 1024 * 1024);
        }
    }

    #[test]
    fn max_tile_rows_caps_band_height() {
        let ops = mk_ops(&[("bn", 0), ("relu", 0)], 8, 64);
        let device = dev(1 << 20);
        let free = collapse(&ops, &device, &CollapseOptions::default());
        assert_eq!(free[0].tile_rows, 64, "huge budget grows to the full plane");
        let capped = collapse(
            &ops,
            &device,
            &CollapseOptions {
                max_tile_rows: Some(4),
                ..Default::default()
            },
        );
        assert_eq!(capped[0].tile_rows, 4);
        let single = collapse(
            &ops,
            &device,
            &CollapseOptions {
                max_tile_rows: Some(1),
                ..Default::default()
            },
        );
        assert_eq!(single[0].tile_rows, 1);
        // The cap wins over a conflicting min_tile_rows.
        let conflict = collapse(
            &ops,
            &device,
            &CollapseOptions {
                min_tile_rows: 8,
                max_tile_rows: Some(2),
                ..Default::default()
            },
        );
        assert_eq!(conflict[0].tile_rows, 2);
        // Huge min_tile_rows without a cap clamps at the plane height.
        let whole = collapse(
            &ops,
            &device,
            &CollapseOptions {
                min_tile_rows: 1 << 20,
                ..Default::default()
            },
        );
        for s in &whole {
            let (out_h, _) = row_geometry(s.out_shape());
            assert_eq!(s.tile_rows, out_h);
        }
    }

    #[test]
    fn elementwise_only_stack_single_step() {
        let ops = mk_ops(&[("bn", 0), ("relu", 0), ("id", 0), ("relu", 0)], 8, 32);
        let seqs = collapse(&ops, &dev(16 * 1024), &CollapseOptions::default());
        assert_eq!(seqs.len(), 1);
        assert_eq!(seqs[0].steps.len(), 1);
        assert!(seqs[0].steps[0].only_elementwise());
    }
}
