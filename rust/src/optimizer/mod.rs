//! The BrainSlug optimizer — the paper's compile phase (§4.1).
//!
//! * [`ops`] — maps optimizable layers onto basic computational
//!   operations (Listing 1 step #2).
//! * [`collapse`] — groups operations into steps and packs steps into
//!   sequences against the device's fast-memory budget (steps #3, #4),
//!   choosing the depth-first band height per sequence.
//! * [`plan`] — the Network Analyzer: detects maximal optimizable chains
//!   (step #1) *and* single-entry/single-exit branch regions
//!   ([`crate::graph::BranchRegion`]), collapses chains into [`Stack`]s
//!   (branch arms against a skip-reserved budget), dedups identical
//!   stacks, and emits the [`Plan`] the scheduler executes (step #5) —
//!   branch regions as [`Segment::Branch`], arms depth-first, joins
//!   fused.
//!
//! Code generation (the paper's step 5 proper) happens on the python side
//! from the same stack signatures: `brainslug emit-requests` serializes
//! every unique stack, `python/compile/aot.py` lowers one fused Pallas
//! kernel per signature to an HLO artifact, and the scheduler binds them
//! back by name at load time.

pub mod collapse;
pub mod ops;
pub mod plan;

pub use collapse::{collapse, effective_budget, reservation_holds, CollapseOptions, Sequence, Step};
pub use ops::{OpKind, Operation};
pub use plan::{fnv64_hex, optimize, Plan, Segment, Stack};
