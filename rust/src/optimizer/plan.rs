//! Network analysis and plan construction (§4.1 steps 1, 2, 5 of
//! Listing 1): walk the DAG, gather maximal runs of optimizable layers
//! into [`Stack`]s, collapse each stack into sequences, and emit an
//! execution [`Plan`] where stacks are replaced by fused-kernel segments
//! — the paper's "special BrainSlug layer".
//!
//! The analyzer is *branch-aware*: chain-only planning (the paper's
//! Listing 1) fragments branchy networks (ResNet, DenseNet, Inception)
//! at every `Add`/`Concat` junction, exactly the workloads Table 2 shows
//! the least headroom on. Here every single-entry/single-exit
//! [`BranchRegion`] becomes one [`Segment::Branch`]: independent stacks
//! are built *inside each arm* (packed against a budget that reserves
//! the live skip-connection plane, see
//! [`CollapseOptions::reserved_bytes`]), the arms execute depth-first
//! one after another, and the join fuses with the final arm instead of
//! launching as a standalone kernel.

use std::collections::HashMap;

use crate::device::DeviceSpec;
use crate::graph::{BranchRegion, Graph, NodeId, Shape};

use super::collapse::{collapse, CollapseOptions, Sequence};
use super::ops::Operation;

/// A detected stack: a maximal chain of consecutive optimizable layers,
/// collapsed into sequences.
#[derive(Debug, Clone)]
pub struct Stack {
    /// Graph nodes absorbed, in execution order.
    pub nodes: Vec<NodeId>,
    pub sequences: Vec<Sequence>,
    /// Canonical structure signature (dedup + artifact naming).
    pub signature: String,
}

impl Stack {
    pub fn in_shape(&self) -> &Shape {
        self.sequences.first().expect("empty stack").in_shape()
    }

    pub fn out_shape(&self) -> &Shape {
        self.sequences.last().expect("empty stack").out_shape()
    }

    pub fn num_ops(&self) -> usize {
        self.sequences.iter().map(|s| s.num_ops()).sum()
    }

    /// Artifact name for this stack's fused executable.
    pub fn artifact_name(&self) -> String {
        format!("stack_{}", fnv64_hex(&self.signature))
    }
}

/// One schedulable unit of the optimized network.
#[derive(Debug, Clone)]
pub enum Segment {
    /// A layer executed as-is (conv, linear, add, concat, flatten, or an
    /// optimizable layer the analyzer chose not to stack).
    Single(NodeId),
    /// A collapsed stack executed by the fused depth-first kernel.
    Stack(Stack),
    /// A branch region executed depth-first arm-by-arm: each arm is a
    /// planned run of `Single`/`Stack` segments (never a nested branch —
    /// arms are unary chains by construction), and `join` is the
    /// `Add`/`Concat` that reconverges them, consumed fused with the
    /// final arm's output instead of dispatched as a standalone kernel.
    Branch {
        /// Arm bodies in join-input order (an empty arm is the identity
        /// skip edge of a residual connection).
        arms: Vec<Vec<Segment>>,
        join: NodeId,
    },
}

impl Segment {
    /// The graph node whose value this segment leaves behind (`None`
    /// only for a degenerate empty stack).
    pub fn output_node(&self) -> Option<NodeId> {
        match self {
            Segment::Single(id) => Some(*id),
            Segment::Stack(st) => st.nodes.last().copied(),
            Segment::Branch { join, .. } => Some(*join),
        }
    }
}

/// The optimized execution plan for one network at one batch size.
#[derive(Debug, Clone)]
pub struct Plan {
    pub network: String,
    pub device: String,
    pub segments: Vec<Segment>,
    /// Stacks deduplicated by signature → representative ordinal in
    /// [`Plan::stacks`] order (the paper generates code once per
    /// distinct stack; branch-arm stacks dedup against each other and
    /// against chain stacks through the same signatures).
    pub unique_stacks: HashMap<String, usize>,
}

/// Collect every stack (chain-level and branch-arm) in execution order.
fn collect_stacks<'a>(segments: &'a [Segment], out: &mut Vec<&'a Stack>) {
    for seg in segments {
        match seg {
            Segment::Single(_) => {}
            Segment::Stack(st) => out.push(st),
            Segment::Branch { arms, .. } => {
                for arm in arms {
                    collect_stacks(arm, out);
                }
            }
        }
    }
}

impl Plan {
    /// Stacks everywhere in the plan (chain-level and inside branch
    /// arms), counted without materializing [`Plan::stacks`].
    pub fn num_stacks(&self) -> usize {
        fn count(seg: &Segment) -> usize {
            match seg {
                Segment::Single(_) => 0,
                Segment::Stack(_) => 1,
                Segment::Branch { arms, .. } => arms.iter().flatten().map(count).sum(),
            }
        }
        self.segments.iter().map(count).sum()
    }

    pub fn num_unique_stacks(&self) -> usize {
        self.unique_stacks.len()
    }

    /// Branch regions executed depth-first arm-by-arm.
    pub fn num_branches(&self) -> usize {
        self.segments
            .iter()
            .filter(|s| matches!(s, Segment::Branch { .. }))
            .count()
    }

    /// Number of graph layers executed by the depth-first optimized
    /// schedule (Table 2 "Opt."): stack members everywhere, plus each
    /// branch join (fused with its final arm rather than launched as a
    /// standalone framework kernel).
    pub fn num_optimized_layers(&self) -> usize {
        fn seg_opt(seg: &Segment) -> usize {
            match seg {
                Segment::Single(_) => 0,
                Segment::Stack(st) => st.nodes.len(),
                Segment::Branch { arms, .. } => {
                    1 + arms.iter().flatten().map(seg_opt).sum::<usize>()
                }
            }
        }
        self.segments.iter().map(seg_opt).sum()
    }

    /// All stacks in execution order, including branch-arm stacks.
    pub fn stacks(&self) -> impl Iterator<Item = &Stack> {
        let mut v = Vec::new();
        collect_stacks(&self.segments, &mut v);
        v.into_iter()
    }

    /// Every node of the graph appears in exactly one segment; stack
    /// chains and branch regions are structurally well-formed. Thin
    /// wrapper over the static plan verifier
    /// (`crate::analysis::verify_structure`): the first error is
    /// rendered as one line for legacy `Result<_, String>` callers;
    /// `brainslug check` surfaces the full diagnostic list, including
    /// the resource proofs (`crate::analysis::verify_resources`).
    pub fn validate(&self, graph: &Graph) -> Result<(), String> {
        let first_error = crate::analysis::verify_structure(graph, self)
            .into_iter()
            .find(|d| d.severity == crate::analysis::Severity::Error);
        match first_error {
            None => Ok(()),
            Some(d) => Err(d.render_oneline()),
        }
    }
}

/// Collapse `nodes` (a consecutive unary chain of optimizable layers)
/// into a [`Stack`].
fn build_stack(
    graph: &Graph,
    nodes: Vec<NodeId>,
    device: &DeviceSpec,
    opts: &CollapseOptions,
) -> Stack {
    let ops: Vec<Operation> = nodes
        .iter()
        .map(|&id| {
            let n = graph.node(id);
            let in_shape = &graph.node(n.inputs[0]).shape;
            Operation::from_layer(id, &n.name, &n.layer, in_shape, &n.shape)
                .expect("chain node must be optimizable")
        })
        .collect();
    let sequences = collapse(&ops, device, opts);
    // The signature captures everything codegen depends on: input
    // shape, per-sequence op structure AND the chosen band height
    // (tile_rows changes the generated kernel's grid).
    let signature = format!(
        "in:{}|{}",
        sequences[0].in_shape().sig(),
        sequences
            .iter()
            .map(|s| format!("{}@t{}", s.sig(), s.tile_rows))
            .collect::<Vec<_>>()
            .join("|")
    );
    Stack {
        nodes,
        sequences,
        signature,
    }
}

/// Flush the open chain into a stack segment (no-op when empty).
fn flush_chain(
    graph: &Graph,
    device: &DeviceSpec,
    opts: &CollapseOptions,
    chain: &mut Vec<NodeId>,
    segments: &mut Vec<Segment>,
) {
    if chain.is_empty() {
        return;
    }
    let nodes = std::mem::take(chain);
    segments.push(Segment::Stack(build_stack(graph, nodes, device, opts)));
}

/// Plan one branch arm: the arm is a unary single-consumer chain, so
/// runs of optimizable layers become stacks and everything else stays a
/// single — the same partition chain-only planning produces, but packed
/// against the arm's reserved (skip-aware) budget.
fn plan_arm(
    graph: &Graph,
    nodes: &[NodeId],
    device: &DeviceSpec,
    opts: &CollapseOptions,
) -> Vec<Segment> {
    let mut segments = Vec::new();
    let mut chain: Vec<NodeId> = Vec::new();
    for &id in nodes {
        if graph.node(id).layer.is_optimizable() {
            chain.push(id);
        } else {
            flush_chain(graph, device, opts, &mut chain, &mut segments);
            segments.push(Segment::Single(id));
        }
    }
    flush_chain(graph, device, opts, &mut chain, &mut segments);
    segments
}

/// Fast-tier bytes the skip connection pins per depth-first work unit
/// while a branch arm executes: one (batch, channel) plane of the entry
/// tensor (one row of a rank-2 activation). The fused join consumes
/// this resident plane band-wise without a main-memory round-trip —
/// the memsim join model (`memsim::perfmodel`) applies the same rule
/// when deciding whether the skip read hits the fast tier.
pub(crate) fn live_plane_bytes(shape: &Shape) -> usize {
    match shape.rank() {
        4 => shape.height() * shape.width() * shape.dtype.bytes(),
        _ => shape.channels() * shape.dtype.bytes(),
    }
}

/// Analyzer + collapse: produce the optimized plan for `graph` on
/// `device`.
///
/// A chain joins a stack while: the layer is optimizable, it consumes
/// the previous chain node, and the previous chain node has a single
/// consumer (fan-out forces materialization — the tail of a stack may
/// fan out, the middle may not). Detected [`BranchRegion`]s are planned
/// as [`Segment::Branch`]: their arm bodies are skipped by the linear
/// walk and planned arm-by-arm (with the skip plane reserved from the
/// collapse budget) when the walk reaches the join.
pub fn optimize(graph: &Graph, device: &DeviceSpec, opts: &CollapseOptions) -> Plan {
    // One consumer map per planning pass, threaded everywhere.
    let consumers = graph.consumer_map();
    let regions: Vec<BranchRegion> = graph.branch_regions(&consumers);
    let mut region_at: HashMap<NodeId, usize> = HashMap::new();
    let mut in_arm = vec![false; graph.nodes.len()];
    for (i, r) in regions.iter().enumerate() {
        region_at.insert(r.join, i);
        for id in r.arm_nodes() {
            in_arm[id] = true;
        }
    }

    let mut segments: Vec<Segment> = Vec::new();
    let mut chain: Vec<NodeId> = Vec::new();
    for node in graph.nodes.iter().skip(1) {
        if in_arm[node.id] {
            // Planned inside its region's branch segment at the join.
            continue;
        }
        if let Some(&ri) = region_at.get(&node.id) {
            flush_chain(graph, device, opts, &mut chain, &mut segments);
            let region = &regions[ri];
            let arm_opts = CollapseOptions {
                reserved_bytes: opts
                    .reserved_bytes
                    .saturating_add(live_plane_bytes(&graph.node(region.entry).shape)),
                ..*opts
            };
            let arms = region
                .arms
                .iter()
                .map(|arm| plan_arm(graph, arm, device, &arm_opts))
                .collect();
            segments.push(Segment::Branch {
                arms,
                join: node.id,
            });
            continue;
        }
        let extends_chain = node.layer.is_optimizable()
            && node.inputs.len() == 1
            && chain
                .last()
                .is_none_or(|&last| node.inputs[0] == last && consumers.is_single(last));
        if extends_chain {
            chain.push(node.id);
        } else {
            flush_chain(graph, device, opts, &mut chain, &mut segments);
            if node.layer.is_optimizable() && node.inputs.len() == 1 {
                // Starts a fresh chain (previous chain was broken by
                // fan-out or non-adjacency).
                chain.push(node.id);
            } else {
                segments.push(Segment::Single(node.id));
            }
        }
    }
    flush_chain(graph, device, opts, &mut chain, &mut segments);

    let mut unique = HashMap::new();
    let mut stacks = Vec::new();
    collect_stacks(&segments, &mut stacks);
    for (i, st) in stacks.iter().enumerate() {
        unique.entry(st.signature.clone()).or_insert(i);
    }

    Plan {
        network: graph.name.clone(),
        device: device.name.clone(),
        segments,
        unique_stacks: unique,
    }
}

/// FNV-1a 64-bit hex digest (stable across rust/python; mirrored in
/// `python/compile/stacks.py`).
pub fn fnv64_hex(s: &str) -> String {
    let mut h: u64 = 0xCBF29CE484222325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001B3);
    }
    format!("{h:016x}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Layer, PoolKind, Window2d};
    use crate::zoo;

    fn device() -> DeviceSpec {
        DeviceSpec::paper_gpu()
    }

    fn simple_net() -> Graph {
        let mut g = Graph::new("t", Shape::nchw(1, 8, 32, 32));
        g.push(
            "conv1",
            Layer::Conv2d {
                out_channels: 8,
                window: Window2d::square(3, 1, 1),
                bias: false,
            },
        );
        g.push("bn1", Layer::BatchNorm2d { eps: 1e-5 });
        g.push("relu1", Layer::Relu);
        g.push(
            "pool1",
            Layer::Pool2d {
                kind: PoolKind::Max,
                window: Window2d::square(2, 2, 0),
                ceil_mode: false,
                count_include_pad: true,
            },
        );
        g.push(
            "conv2",
            Layer::Conv2d {
                out_channels: 8,
                window: Window2d::square(3, 1, 1),
                bias: false,
            },
        );
        g.push("relu2", Layer::Relu);
        g
    }

    /// A residual block: x -> conv -> bn -> add(x) -> relu.
    fn residual_net() -> Graph {
        let mut g = Graph::new("res", Shape::nchw(1, 8, 16, 16));
        g.push("bn_in", Layer::BatchNorm2d { eps: 1e-5 });
        let x = g.push("relu_in", Layer::Relu);
        let c = g.add(
            "conv",
            Layer::Conv2d {
                out_channels: 8,
                window: Window2d::square(3, 1, 1),
                bias: false,
            },
            &[x],
        );
        let b = g.add("bn", Layer::BatchNorm2d { eps: 1e-5 }, &[c]);
        g.add("add", Layer::Add, &[b, x]);
        g.push("relu_out", Layer::Relu);
        g
    }

    #[test]
    fn detects_bn_relu_pool_stack() {
        let g = simple_net();
        let plan = optimize(&g, &device(), &CollapseOptions::default());
        plan.validate(&g).unwrap();
        assert_eq!(plan.num_stacks(), 2); // [bn relu pool] and [relu2]
        assert_eq!(plan.num_optimized_layers(), 4);
        let first = plan.stacks().next().unwrap();
        assert_eq!(first.sequences[0].sig(), "bn,relu,maxpool_k2x2s2x2p0x0");
    }

    #[test]
    fn fanout_breaks_chains() {
        // residual: relu output feeds both conv and add.
        let mut g = Graph::new("res", Shape::nchw(1, 8, 16, 16));
        g.push("bn", Layer::BatchNorm2d { eps: 1e-5 });
        let r = g.push("relu", Layer::Relu);
        let c = g.add(
            "conv",
            Layer::Conv2d {
                out_channels: 8,
                window: Window2d::square(3, 1, 1),
                bias: false,
            },
            &[r],
        );
        g.add("add", Layer::Add, &[c, r]);
        let plan = optimize(&g, &device(), &CollapseOptions::default());
        plan.validate(&g).unwrap();
        // bn+relu stack ends at relu (fan-out at its OUTPUT is fine since
        // the stack result is materialized); the conv+add tail becomes a
        // branch region whose arm holds the conv.
        let st = plan.stacks().next().unwrap();
        assert_eq!(st.nodes.len(), 2);
        assert_eq!(plan.num_branches(), 1);
    }

    #[test]
    fn fanout_inside_chain_splits() {
        // bn -> relu(fan-out) -> dropout: relu's output is consumed by
        // dropout AND add, so dropout cannot join bn+relu's stack — it
        // becomes the single-node stack of the branch's dropout arm.
        let mut g = Graph::new("fan", Shape::nchw(1, 8, 16, 16));
        g.push("bn", Layer::BatchNorm2d { eps: 1e-5 });
        let r = g.push("relu", Layer::Relu);
        let d = g.add("dropout", Layer::Dropout { p: 0.1 }, &[r]);
        g.add("add", Layer::Add, &[d, r]);
        let plan = optimize(&g, &device(), &CollapseOptions::default());
        plan.validate(&g).unwrap();
        let stacks: Vec<&Stack> = plan.stacks().collect();
        assert_eq!(stacks.len(), 2);
        assert_eq!(stacks[0].nodes.len(), 2); // bn, relu
        assert_eq!(stacks[1].nodes.len(), 1); // dropout alone (in the arm)
        assert_eq!(plan.num_branches(), 1);
    }

    #[test]
    fn residual_region_becomes_branch_segment() {
        let g = residual_net();
        let plan = optimize(&g, &device(), &CollapseOptions::default());
        plan.validate(&g).unwrap();
        assert_eq!(plan.num_branches(), 1);
        let branch = plan
            .segments
            .iter()
            .find_map(|s| match s {
                Segment::Branch { arms, join } => Some((arms, *join)),
                _ => None,
            })
            .expect("plan has a branch segment");
        let (arms, join) = branch;
        assert_eq!(g.node(join).layer.kind_name(), "add");
        assert_eq!(arms.len(), 2);
        // Main arm: Single(conv) + Stack([bn]); skip arm: identity.
        assert_eq!(arms[0].len(), 2);
        assert!(arms[1].is_empty());
        // The join counts as optimized: bn_in+relu_in (2) + bn (1) +
        // relu_out (1) + join (1).
        assert_eq!(plan.num_optimized_layers(), 5);
    }

    #[test]
    fn arm_stacks_reserve_skip_plane() {
        // The bn stack inside the arm packs against a reduced budget, so
        // at a large enough plane its band is shorter than the same
        // stack's outside a branch.
        let mut g = Graph::new("res", Shape::nchw(1, 8, 64, 64));
        let x = g.output;
        let c = g.add(
            "conv",
            Layer::Conv2d {
                out_channels: 8,
                window: Window2d::square(3, 1, 1),
                bias: false,
            },
            &[x],
        );
        let b = g.add("bn", Layer::BatchNorm2d { eps: 1e-5 }, &[c]);
        let b2 = g.add("relu", Layer::Relu, &[b]);
        g.add("add", Layer::Add, &[b2, x]);
        let plan = optimize(&g, &device(), &CollapseOptions::default());
        plan.validate(&g).unwrap();
        let arm_stack = plan.stacks().next().unwrap();
        // Chain context: same ops collapsed with no reservation.
        let mut lin = Graph::new("lin", Shape::nchw(1, 8, 64, 64));
        lin.push(
            "conv",
            Layer::Conv2d {
                out_channels: 8,
                window: Window2d::square(3, 1, 1),
                bias: false,
            },
        );
        lin.push("bn", Layer::BatchNorm2d { eps: 1e-5 });
        lin.push("relu", Layer::Relu);
        let lin_plan = optimize(&lin, &device(), &CollapseOptions::default());
        let lin_stack = lin_plan.stacks().next().unwrap();
        assert!(
            arm_stack.sequences[0].tile_rows < lin_stack.sequences[0].tile_rows,
            "arm tile {} !< chain tile {}",
            arm_stack.sequences[0].tile_rows,
            lin_stack.sequences[0].tile_rows
        );
        assert_ne!(arm_stack.signature, lin_stack.signature);
    }

    #[test]
    fn identical_arm_stacks_dedup_across_branches() {
        // Two identical residual blocks: the per-arm stacks share
        // signatures across the two branch segments.
        let mut g = Graph::new("res2", Shape::nchw(1, 8, 16, 16));
        for i in 0..2 {
            let x = g.output;
            let c = g.add(
                format!("conv{i}"),
                Layer::Conv2d {
                    out_channels: 8,
                    window: Window2d::square(3, 1, 1),
                    bias: false,
                },
                &[x],
            );
            let b = g.add(format!("bn{i}"), Layer::BatchNorm2d { eps: 1e-5 }, &[c]);
            g.add(format!("add{i}"), Layer::Add, &[b, x]);
            g.push(format!("relu{i}"), Layer::Relu);
        }
        let plan = optimize(&g, &device(), &CollapseOptions::default());
        plan.validate(&g).unwrap();
        assert_eq!(plan.num_branches(), 2);
        // Stacks: 2x arm [bn], 2x chain [relu] — each pair dedups.
        assert_eq!(plan.num_stacks(), 4);
        assert_eq!(plan.num_unique_stacks(), 2);
    }

    #[test]
    fn validate_rejects_corrupted_branch() {
        let g = residual_net();
        let mut plan = optimize(&g, &device(), &CollapseOptions::default());
        plan.validate(&g).unwrap();
        // Swap the join for a non-join node: validation must fail loudly.
        for seg in &mut plan.segments {
            if let Segment::Branch { join, .. } = seg {
                *join -= 1;
            }
        }
        assert!(plan.validate(&g).is_err());
    }

    #[test]
    fn identical_stacks_dedup() {
        // Two identical conv->relu->pool blocks: both relu+pool stacks
        // share one signature.
        let mut g = Graph::new("dup", Shape::nchw(1, 8, 32, 32));
        for i in 0..2 {
            g.push(
                format!("conv{i}"),
                Layer::Conv2d {
                    out_channels: 8,
                    window: Window2d::square(3, 1, 1),
                    bias: false,
                },
            );
            g.push(format!("relu{i}"), Layer::Relu);
        }
        let plan = optimize(&g, &device(), &CollapseOptions::default());
        assert_eq!(plan.num_stacks(), 2);
        assert_eq!(plan.num_unique_stacks(), 1);
    }

    #[test]
    fn zoo_plans_validate_and_match_table2_regime() {
        for name in ["alexnet", "resnet18", "densenet121", "vgg16_bn", "squeezenet1_0"] {
            let g = zoo::build(name, zoo::paper_config(name, 1));
            let plan = optimize(&g, &device(), &CollapseOptions::default());
            plan.validate(&g).unwrap();
            let frac = plan.num_optimized_layers() as f64 / g.num_layers() as f64;
            // Paper Table 2: 44-64% of layers are optimizable; fused
            // branch joins push our branchy nets slightly above.
            assert!(
                (0.25..0.75).contains(&frac),
                "{name}: optimized fraction {frac:.2} out of regime"
            );
            assert!(plan.num_unique_stacks() <= plan.num_stacks());
        }
    }

    #[test]
    fn fnv_is_stable() {
        assert_eq!(fnv64_hex(""), "cbf29ce484222325");
        assert_eq!(fnv64_hex("a"), "af63dc4c8601ec8c");
        // Regression pin: stack signatures hash deterministically.
        let h1 = fnv64_hex("in:1x8x32x32f32|bn,relu");
        assert_eq!(h1, fnv64_hex("in:1x8x32x32f32|bn,relu"));
    }

    #[test]
    fn batch_change_changes_signature_but_not_structure() {
        let g = simple_net();
        let p1 = optimize(&g, &device(), &CollapseOptions::default());
        let p8 = optimize(&g.with_batch(8), &device(), &CollapseOptions::default());
        assert_eq!(p1.num_stacks(), p8.num_stacks());
        let s1 = p1.stacks().next().unwrap();
        let s8 = p8.stacks().next().unwrap();
        assert_ne!(s1.signature, s8.signature); // shape is in signature
    }

    #[test]
    fn branch_structure_is_batch_invariant() {
        let g = residual_net();
        let p1 = optimize(&g, &device(), &CollapseOptions::default());
        let p8 = optimize(&g.with_batch(8), &device(), &CollapseOptions::default());
        assert_eq!(p1.num_branches(), p8.num_branches());
        assert_eq!(p1.num_stacks(), p8.num_stacks());
        assert_eq!(p1.num_optimized_layers(), p8.num_optimized_layers());
    }
}
