//! Network analysis and plan construction (§4.1 steps 1, 2, 5 of
//! Listing 1): walk the DAG, gather maximal runs of optimizable layers
//! into [`Stack`]s, collapse each stack into sequences, and emit an
//! execution [`Plan`] where stacks are replaced by fused-kernel segments
//! — the paper's "special BrainSlug layer".

use std::collections::HashMap;

use crate::device::DeviceSpec;
use crate::graph::{Graph, NodeId, Shape};

use super::collapse::{collapse, CollapseOptions, Sequence};
use super::ops::Operation;

/// A detected stack: a maximal chain of consecutive optimizable layers,
/// collapsed into sequences.
#[derive(Debug, Clone)]
pub struct Stack {
    /// Graph nodes absorbed, in execution order.
    pub nodes: Vec<NodeId>,
    pub sequences: Vec<Sequence>,
    /// Canonical structure signature (dedup + artifact naming).
    pub signature: String,
}

impl Stack {
    pub fn in_shape(&self) -> &Shape {
        self.sequences.first().expect("empty stack").in_shape()
    }

    pub fn out_shape(&self) -> &Shape {
        self.sequences.last().expect("empty stack").out_shape()
    }

    pub fn num_ops(&self) -> usize {
        self.sequences.iter().map(|s| s.num_ops()).sum()
    }

    /// Artifact name for this stack's fused executable.
    pub fn artifact_name(&self) -> String {
        format!("stack_{}", fnv64_hex(&self.signature))
    }
}

/// One schedulable unit of the optimized network.
#[derive(Debug, Clone)]
pub enum Segment {
    /// A layer executed as-is (conv, linear, add, concat, flatten, or an
    /// optimizable layer the analyzer chose not to stack).
    Single(NodeId),
    /// A collapsed stack executed by the fused depth-first kernel.
    Stack(Stack),
}

/// The optimized execution plan for one network at one batch size.
#[derive(Debug, Clone)]
pub struct Plan {
    pub network: String,
    pub device: String,
    pub segments: Vec<Segment>,
    /// Stacks deduplicated by signature → representative index in
    /// `segments` (the paper generates code once per distinct stack).
    pub unique_stacks: HashMap<String, usize>,
}

impl Plan {
    pub fn num_stacks(&self) -> usize {
        self.segments
            .iter()
            .filter(|s| matches!(s, Segment::Stack(_)))
            .count()
    }

    pub fn num_unique_stacks(&self) -> usize {
        self.unique_stacks.len()
    }

    /// Number of graph layers absorbed into stacks (Table 2 "Opt.").
    pub fn num_optimized_layers(&self) -> usize {
        self.segments
            .iter()
            .map(|s| match s {
                Segment::Stack(st) => st.nodes.len(),
                Segment::Single(_) => 0,
            })
            .sum()
    }

    /// All stacks in execution order.
    pub fn stacks(&self) -> impl Iterator<Item = &Stack> {
        self.segments.iter().filter_map(|s| match s {
            Segment::Stack(st) => Some(st),
            Segment::Single(_) => None,
        })
    }

    /// Every node of the graph appears in exactly one segment; verify.
    pub fn validate(&self, graph: &Graph) -> Result<(), String> {
        let mut seen = vec![false; graph.nodes.len()];
        seen[0] = true; // input placeholder is implicit
        let mut mark = |id: NodeId| -> Result<(), String> {
            if seen[id] {
                return Err(format!("node {id} appears twice in plan"));
            }
            seen[id] = true;
            Ok(())
        };
        for seg in &self.segments {
            match seg {
                Segment::Single(id) => mark(*id)?,
                Segment::Stack(st) => {
                    for &id in &st.nodes {
                        mark(id)?;
                    }
                    // Stack nodes must form a consecutive unary chain.
                    for w in st.nodes.windows(2) {
                        let node = graph.node(w[1]);
                        if node.inputs != [w[0]] {
                            return Err(format!(
                                "stack chain broken between {} and {}",
                                w[0], w[1]
                            ));
                        }
                    }
                }
            }
        }
        if let Some(missing) = seen.iter().position(|s| !s) {
            return Err(format!("node {missing} missing from plan"));
        }
        Ok(())
    }
}

/// Analyzer + collapse: produce the optimized plan for `graph` on
/// `device`.
///
/// A chain joins a stack while: the layer is optimizable, it consumes the
/// previous chain node, and the previous chain node has a single consumer
/// (fan-out forces materialization — the tail of a stack may fan out, the
/// middle may not).
pub fn optimize(graph: &Graph, device: &DeviceSpec, opts: &CollapseOptions) -> Plan {
    let single = graph.single_consumer();
    let mut segments: Vec<Segment> = Vec::new();
    let mut chain: Vec<NodeId> = Vec::new();

    let flush = |chain: &mut Vec<NodeId>, segments: &mut Vec<Segment>| {
        if chain.is_empty() {
            return;
        }
        let ops: Vec<Operation> = chain
            .iter()
            .map(|&id| {
                let n = graph.node(id);
                let in_shape = &graph.node(n.inputs[0]).shape;
                Operation::from_layer(id, &n.name, &n.layer, in_shape, &n.shape)
                    .expect("chain node must be optimizable")
            })
            .collect();
        let sequences = collapse(&ops, device, opts);
        // The signature captures everything codegen depends on: input
        // shape, per-sequence op structure AND the chosen band height
        // (tile_rows changes the generated kernel's grid).
        let signature = format!(
            "in:{}|{}",
            sequences[0].in_shape().sig(),
            sequences
                .iter()
                .map(|s| format!("{}@t{}", s.sig(), s.tile_rows))
                .collect::<Vec<_>>()
                .join("|")
        );
        segments.push(Segment::Stack(Stack {
            nodes: std::mem::take(chain),
            sequences,
            signature,
        }));
    };

    for node in graph.nodes.iter().skip(1) {
        let extends_chain = node.layer.is_optimizable()
            && node.inputs.len() == 1
            && chain
                .last()
                .is_none_or(|&last| node.inputs[0] == last && single[last]);
        if extends_chain {
            if chain.is_empty() {
                // A new chain can start anywhere (its input comes from
                // main memory regardless).
            }
            chain.push(node.id);
        } else {
            flush(&mut chain, &mut segments);
            if node.layer.is_optimizable() && node.inputs.len() == 1 {
                // Starts a fresh chain (previous chain was broken by
                // fan-out or non-adjacency).
                chain.push(node.id);
            } else {
                segments.push(Segment::Single(node.id));
            }
        }
    }
    flush(&mut chain, &mut segments);

    let mut unique = HashMap::new();
    for (i, seg) in segments.iter().enumerate() {
        if let Segment::Stack(st) = seg {
            unique.entry(st.signature.clone()).or_insert(i);
        }
    }

    Plan {
        network: graph.name.clone(),
        device: device.name.clone(),
        segments,
        unique_stacks: unique,
    }
}

/// FNV-1a 64-bit hex digest (stable across rust/python; mirrored in
/// `python/compile/stacks.py`).
pub fn fnv64_hex(s: &str) -> String {
    let mut h: u64 = 0xCBF29CE484222325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001B3);
    }
    format!("{h:016x}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Layer, PoolKind, Window2d};
    use crate::zoo;

    fn device() -> DeviceSpec {
        DeviceSpec::paper_gpu()
    }

    fn simple_net() -> Graph {
        let mut g = Graph::new("t", Shape::nchw(1, 8, 32, 32));
        g.push(
            "conv1",
            Layer::Conv2d {
                out_channels: 8,
                window: Window2d::square(3, 1, 1),
                bias: false,
            },
        );
        g.push("bn1", Layer::BatchNorm2d { eps: 1e-5 });
        g.push("relu1", Layer::Relu);
        g.push(
            "pool1",
            Layer::Pool2d {
                kind: PoolKind::Max,
                window: Window2d::square(2, 2, 0),
                ceil_mode: false,
                count_include_pad: true,
            },
        );
        g.push(
            "conv2",
            Layer::Conv2d {
                out_channels: 8,
                window: Window2d::square(3, 1, 1),
                bias: false,
            },
        );
        g.push("relu2", Layer::Relu);
        g
    }

    #[test]
    fn detects_bn_relu_pool_stack() {
        let g = simple_net();
        let plan = optimize(&g, &device(), &CollapseOptions::default());
        plan.validate(&g).unwrap();
        assert_eq!(plan.num_stacks(), 2); // [bn relu pool] and [relu2]
        assert_eq!(plan.num_optimized_layers(), 4);
        let first = plan.stacks().next().unwrap();
        assert_eq!(first.sequences[0].sig(), "bn,relu,maxpool_k2x2s2x2p0x0");
    }

    #[test]
    fn fanout_breaks_chains() {
        // residual: relu output feeds both conv and add.
        let mut g = Graph::new("res", Shape::nchw(1, 8, 16, 16));
        g.push("bn", Layer::BatchNorm2d { eps: 1e-5 });
        let r = g.push("relu", Layer::Relu);
        let c = g.add(
            "conv",
            Layer::Conv2d {
                out_channels: 8,
                window: Window2d::square(3, 1, 1),
                bias: false,
            },
            &[r],
        );
        g.add("add", Layer::Add, &[c, r]);
        let plan = optimize(&g, &device(), &CollapseOptions::default());
        plan.validate(&g).unwrap();
        // bn+relu stack ends at relu (fan-out at its OUTPUT is fine since
        // the stack result is materialized); conv and add are singles.
        let st = plan.stacks().next().unwrap();
        assert_eq!(st.nodes.len(), 2);
    }

    #[test]
    fn fanout_inside_chain_splits() {
        // bn -> relu(fan-out) -> dropout: relu's output is consumed by
        // dropout AND add, so dropout cannot join bn+relu's stack.
        let mut g = Graph::new("fan", Shape::nchw(1, 8, 16, 16));
        g.push("bn", Layer::BatchNorm2d { eps: 1e-5 });
        let r = g.push("relu", Layer::Relu);
        let d = g.add("dropout", Layer::Dropout { p: 0.1 }, &[r]);
        g.add("add", Layer::Add, &[d, r]);
        let plan = optimize(&g, &device(), &CollapseOptions::default());
        plan.validate(&g).unwrap();
        let stacks: Vec<&Stack> = plan.stacks().collect();
        assert_eq!(stacks.len(), 2);
        assert_eq!(stacks[0].nodes.len(), 2); // bn, relu
        assert_eq!(stacks[1].nodes.len(), 1); // dropout alone
    }

    #[test]
    fn identical_stacks_dedup() {
        // Two identical conv->relu->pool blocks: both relu+pool stacks
        // share one signature.
        let mut g = Graph::new("dup", Shape::nchw(1, 8, 32, 32));
        for i in 0..2 {
            g.push(
                format!("conv{i}"),
                Layer::Conv2d {
                    out_channels: 8,
                    window: Window2d::square(3, 1, 1),
                    bias: false,
                },
            );
            g.push(format!("relu{i}"), Layer::Relu);
        }
        let plan = optimize(&g, &device(), &CollapseOptions::default());
        assert_eq!(plan.num_stacks(), 2);
        assert_eq!(plan.num_unique_stacks(), 1);
    }

    #[test]
    fn zoo_plans_validate_and_match_table2_regime() {
        for name in ["alexnet", "resnet18", "densenet121", "vgg16_bn", "squeezenet1_0"] {
            let g = zoo::build(name, zoo::paper_config(name, 1));
            let plan = optimize(&g, &device(), &CollapseOptions::default());
            plan.validate(&g).unwrap();
            let frac = plan.num_optimized_layers() as f64 / g.num_layers() as f64;
            // Paper Table 2: 44-64% of layers are optimizable.
            assert!(
                (0.25..0.75).contains(&frac),
                "{name}: optimized fraction {frac:.2} out of regime"
            );
            assert!(plan.num_unique_stacks() <= plan.num_stacks());
        }
    }

    #[test]
    fn fnv_is_stable() {
        assert_eq!(fnv64_hex(""), "cbf29ce484222325");
        assert_eq!(fnv64_hex("a"), "af63dc4c8601ec8c");
        // Regression pin: stack signatures hash deterministically.
        let h1 = fnv64_hex("in:1x8x32x32f32|bn,relu");
        assert_eq!(h1, fnv64_hex("in:1x8x32x32f32|bn,relu"));
    }

    #[test]
    fn batch_change_changes_signature_but_not_structure() {
        let g = simple_net();
        let p1 = optimize(&g, &device(), &CollapseOptions::default());
        let p8 = optimize(&g.with_batch(8), &device(), &CollapseOptions::default());
        assert_eq!(p1.num_stacks(), p8.num_stacks());
        let s1 = p1.stacks().next().unwrap();
        let s8 = p8.stacks().next().unwrap();
        assert_ne!(s1.signature, s8.signature); // shape is in signature
    }
}
