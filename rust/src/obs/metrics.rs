//! Typed metrics: the shared fixed-bucket histogram (extracted from
//! `server::ServerStats`, which now reuses it), a get-or-create registry
//! for labeled histogram series, and a Prometheus text-exposition
//! renderer (`text/plain; version=0.0.4`) behind `GET /v1/metrics`.
//!
//! One histogram implementation serves every consumer — the serving
//! stack's end-to-end latency distribution, the per-segment
//! execution-time series recorded by the batch loop, and the fig16/
//! fig18 percentile columns — so the 12.5 % bucket-midpoint contract is
//! stated (and tested) exactly once.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// First octave with sub-bucket resolution (values below `2^4 = 16` µs
/// get one bucket per microsecond).
const HIST_LINEAR: usize = 16;
const HIST_FIRST_OCTAVE: usize = 4;
const HIST_LAST_OCTAVE: usize = 35;
const HIST_BUCKETS: usize = HIST_LINEAR + (HIST_LAST_OCTAVE - HIST_FIRST_OCTAVE + 1) * 4;

/// Every 8th bucket edge becomes a Prometheus `le` boundary: 18
/// cumulative buckets plus `+Inf` keep the exposition readable while
/// the native 144-bucket resolution still backs percentile queries.
const EXPO_STRIDE: usize = 8;

/// Worst-case relative error of a percentile read against the raw
/// observation it summarizes: above 16 µs a value lands within 12.5 %
/// of its bucket midpoint (four linear sub-buckets per octave), exact
/// below. Documented wherever bucket-derived percentiles are compared
/// against raw-sample percentiles (`/v1/stats` vs the load generator).
pub const MIDPOINT_REL_ERROR: f64 = 0.125;

/// Allocation-free fixed-bucket latency histogram (HdrHistogram-style
/// two-significant-bit layout): microsecond-resolution below 16 µs,
/// then four linear sub-buckets per power-of-two octave, so any
/// recorded value lands within 12.5 % of its bucket midpoint. The hot
/// path is two atomic increments; percentile queries walk the fixed
/// bucket array. Covers up to ~2^36 µs (≈19 h); larger values clamp
/// into the top bucket.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    /// Sum of recorded values in microseconds (the Prometheus `_sum`).
    sum_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            sum_us: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    fn index(us: u64) -> usize {
        if us < HIST_LINEAR as u64 {
            return us as usize;
        }
        let octave = (63 - us.leading_zeros() as usize).min(HIST_LAST_OCTAVE);
        let sub = ((us >> (octave - 2)) & 0b11) as usize;
        HIST_LINEAR + (octave - HIST_FIRST_OCTAVE) * 4 + sub
    }

    /// Bucket midpoint in microseconds.
    fn midpoint_us(idx: usize) -> f64 {
        if idx < HIST_LINEAR {
            return idx as f64 + 0.5;
        }
        let octave = HIST_FIRST_OCTAVE + (idx - HIST_LINEAR) / 4;
        let sub = (idx - HIST_LINEAR) % 4;
        (1u64 << octave) as f64 + (sub as f64 + 0.5) * (1u64 << (octave - 2)) as f64
    }

    /// Upper edge of bucket `idx` in microseconds — the Prometheus
    /// `le` boundary.
    fn bound_us(idx: usize) -> f64 {
        if idx < HIST_LINEAR {
            return (idx + 1) as f64;
        }
        let octave = HIST_FIRST_OCTAVE + (idx - HIST_LINEAR) / 4;
        let sub = (idx - HIST_LINEAR) % 4;
        (1u64 << octave) as f64 + (sub as f64 + 1.0) * (1u64 << (octave - 2)) as f64
    }

    /// Record one observation (microseconds).
    ///
    /// Ordering: Relaxed — bucket counts and the sum are independent
    /// monotone counters and readers tolerate a torn (per-atomic,
    /// cross-atomic unordered) snapshot by construction; see the
    /// `ServerStats` memory-ordering contract.
    pub fn record(&self, us: u64) {
        self.buckets[Self::index(us)].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    /// Total recorded observations.
    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Sum of recorded observations in seconds (the `_sum` sample).
    pub fn sum_seconds(&self) -> f64 {
        self.sum_us.load(Ordering::Relaxed) as f64 / 1e6
    }

    fn snapshot(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    /// `q`-quantile (`0.0 ..= 1.0`) in milliseconds, `0.0` before any
    /// observation. Nearest-rank over the bucket midpoints — accurate
    /// to [`MIDPOINT_REL_ERROR`] against the raw observations.
    pub fn percentile_ms(&self, q: f64) -> f64 {
        let counts = self.snapshot();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (idx, c) in counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Self::midpoint_us(idx) / 1000.0;
            }
        }
        Self::midpoint_us(HIST_BUCKETS - 1) / 1000.0
    }
}

/// One labeled family of histogram series (e.g. per-segment execution
/// times keyed by segment label).
#[derive(Debug)]
struct Family {
    help: String,
    label: String,
    series: BTreeMap<String, Arc<Histogram>>,
}

/// Get-or-create registry of labeled histogram families. Lookup takes
/// one short mutex hold; the returned `Arc<Histogram>` is cached by
/// callers on their hot path so steady-state recording never touches
/// the registry lock.
#[derive(Debug, Default)]
pub struct Registry {
    families: Mutex<BTreeMap<String, Family>>,
}

impl Registry {
    /// The histogram for `(family, label=value)`, created on first use.
    /// `help` and `label` are fixed by the first caller of a family.
    pub fn histogram(
        &self,
        family: &str,
        help: &str,
        label: &str,
        value: &str,
    ) -> Arc<Histogram> {
        let mut families = self.families.lock().unwrap_or_else(|p| p.into_inner());
        let fam = families.entry(family.to_string()).or_insert_with(|| Family {
            help: help.to_string(),
            label: label.to_string(),
            series: BTreeMap::new(),
        });
        fam.series
            .entry(value.to_string())
            .or_insert_with(|| Arc::new(Histogram::default()))
            .clone()
    }

    /// Number of registered series across all families.
    pub fn series_count(&self) -> usize {
        let families = self.families.lock().unwrap_or_else(|p| p.into_inner());
        families.values().map(|f| f.series.len()).sum()
    }

    /// Render every registered family into `exp`, series in
    /// deterministic (BTreeMap) order.
    pub fn render(&self, exp: &mut Exposition) {
        let families = self.families.lock().unwrap_or_else(|p| p.into_inner());
        for (name, fam) in families.iter() {
            for (value, hist) in &fam.series {
                exp.histogram_seconds(name, &fam.help, &[(&fam.label, value)], hist);
            }
        }
    }
}

/// Prometheus text-exposition builder (`text/plain; version=0.0.4`):
/// `# HELP` / `# TYPE` once per family, then one sample line per
/// series. Histograms render cumulative `_bucket{le=...}` lines (in
/// seconds), `_sum` and `_count`.
#[derive(Debug, Default)]
pub struct Exposition {
    out: String,
    typed: std::collections::BTreeSet<String>,
}

impl Exposition {
    pub fn new() -> Self {
        Exposition::default()
    }

    fn header(&mut self, name: &str, help: &str, kind: &str) {
        if self.typed.insert(name.to_string()) {
            self.out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
        }
    }

    fn label_block(labels: &[(&str, &str)]) -> String {
        if labels.is_empty() {
            return String::new();
        }
        let parts: Vec<String> = labels.iter().map(|(k, v)| format!("{k}=\"{v}\"")).collect();
        format!("{{{}}}", parts.join(","))
    }

    pub fn counter(&mut self, name: &str, help: &str, labels: &[(&str, &str)], value: u64) {
        self.header(name, help, "counter");
        self.out.push_str(&format!("{name}{} {value}\n", Self::label_block(labels)));
    }

    pub fn gauge(&mut self, name: &str, help: &str, labels: &[(&str, &str)], value: f64) {
        self.header(name, help, "gauge");
        self.out.push_str(&format!("{name}{} {value}\n", Self::label_block(labels)));
    }

    pub fn histogram_seconds(
        &mut self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        h: &Histogram,
    ) {
        self.header(name, help, "histogram");
        let counts = h.snapshot();
        let mut cum = 0u64;
        for (idx, c) in counts.iter().enumerate() {
            cum += c;
            if (idx + 1) % EXPO_STRIDE == 0 {
                let le = Histogram::bound_us(idx) / 1e6;
                self.bucket_line(name, labels, &format!("{le}"), cum);
            }
        }
        self.bucket_line(name, labels, "+Inf", cum);
        let lb = Self::label_block(labels);
        self.out.push_str(&format!("{name}_sum{lb} {}\n", h.sum_seconds()));
        self.out.push_str(&format!("{name}_count{lb} {cum}\n"));
    }

    fn bucket_line(&mut self, name: &str, labels: &[(&str, &str)], le: &str, cum: u64) {
        let mut all: Vec<(&str, &str)> = labels.to_vec();
        all.push(("le", le));
        self.out.push_str(&format!("{name}_bucket{} {cum}\n", Self::label_block(&all)));
    }

    /// The finished exposition body.
    pub fn finish(self) -> String {
        self.out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_are_monotone_and_tight() {
        // Index is monotone in the value and the midpoint estimate is
        // within 12.5 % above 16 µs (exact below).
        let mut last = 0usize;
        for us in [0u64, 1, 7, 15, 16, 17, 31, 32, 100, 1000, 65_536, 1 << 30] {
            let idx = Histogram::index(us);
            assert!(idx >= last, "index not monotone at {us}");
            last = idx;
            let mid = Histogram::midpoint_us(idx);
            if us < 16 {
                assert!((mid - (us as f64 + 0.5)).abs() < 1e-9, "{us}");
            } else {
                let rel = (mid - us as f64).abs() / us as f64;
                assert!(rel <= 0.30, "us={us} mid={mid} rel={rel}");
            }
        }
        // Absurd values clamp into the top bucket instead of panicking.
        assert_eq!(Histogram::index(u64::MAX), HIST_BUCKETS - 1);
    }

    #[test]
    fn histogram_bounds_bracket_their_bucket() {
        // Every recorded value falls at or below its bucket's upper
        // edge and above the previous bucket's edge — the property the
        // cumulative `le` exposition relies on.
        for us in [0u64, 1, 15, 16, 17, 100, 999, 65_535, 1 << 20] {
            let idx = Histogram::index(us);
            assert!((us as f64) < Histogram::bound_us(idx), "us={us} idx={idx}");
            if idx > 0 {
                assert!((us as f64) >= Histogram::bound_us(idx - 1), "us={us} idx={idx}");
            }
        }
        // Bounds are strictly increasing, so cumulative counts are
        // monotone per series.
        for idx in 1..HIST_BUCKETS {
            assert!(Histogram::bound_us(idx) > Histogram::bound_us(idx - 1), "{idx}");
        }
    }

    #[test]
    fn histogram_percentiles() {
        let h = Histogram::default();
        assert_eq!(h.percentile_ms(0.5), 0.0, "empty histogram is 0.0, not NaN");
        // 100 observations at 1 ms, 10 at 100 ms: p50 ≈ 1 ms, p99+ ≈ 100 ms.
        for _ in 0..100 {
            h.record(1_000);
        }
        for _ in 0..10 {
            h.record(100_000);
        }
        assert_eq!(h.count(), 110);
        let p50 = h.percentile_ms(0.50);
        let p99 = h.percentile_ms(0.99);
        assert!((0.8..=1.3).contains(&p50), "p50 {p50}");
        assert!((80.0..=130.0).contains(&p99), "p99 {p99}");
        assert!(h.percentile_ms(0.0) <= p50 && p50 <= p99);
        assert!(p99 <= h.percentile_ms(1.0) + 1e-9);
        // `_sum` tracks the raw microsecond total exactly.
        assert!((h.sum_seconds() - (100.0 * 0.001 + 10.0 * 0.1)).abs() < 1e-9);
    }

    #[test]
    fn registry_get_or_create_is_stable() {
        let r = Registry::default();
        let a = r.histogram("seg_seconds", "per-segment time", "segment", "seg0");
        let b = r.histogram("seg_seconds", "per-segment time", "segment", "seg0");
        assert!(Arc::ptr_eq(&a, &b), "same series must share one histogram");
        let c = r.histogram("seg_seconds", "per-segment time", "segment", "seg1");
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(r.series_count(), 2);
        a.record(500);
        assert_eq!(b.count(), 1);
    }

    #[test]
    fn exposition_is_valid_prometheus_text() {
        let mut exp = Exposition::new();
        exp.counter("bs_requests_total", "Requests served.", &[], 7);
        exp.counter("bs_worker_total", "Per-worker batches.", &[("worker", "0")], 3);
        exp.counter("bs_worker_total", "Per-worker batches.", &[("worker", "1")], 4);
        exp.gauge("bs_queue_depth", "Queue occupancy.", &[], 2.0);
        let h = Histogram::default();
        h.record(10);
        h.record(10_000);
        exp.histogram_seconds("bs_latency_seconds", "Latency.", &[], &h);
        let text = exp.finish();

        // HELP/TYPE exactly once per family.
        assert_eq!(text.matches("# TYPE bs_worker_total counter").count(), 1);
        assert!(text.contains("bs_requests_total 7\n"));
        assert!(text.contains("bs_worker_total{worker=\"1\"} 4\n"));
        assert!(text.contains("bs_queue_depth 2\n"));
        assert!(text.contains("# TYPE bs_latency_seconds histogram"));
        assert!(text.contains("bs_latency_seconds_bucket{le=\"+Inf\"} 2\n"));
        assert!(text.contains("bs_latency_seconds_count 2\n"));

        // Every non-comment line is `name{labels} value` with a finite
        // numeric value, and histogram cumulative counts are monotone.
        let mut last_bucket = 0u64;
        for line in text.lines() {
            if line.starts_with('#') || line.is_empty() {
                continue;
            }
            let (name, value) = line.rsplit_once(' ').expect("sample line");
            assert!(!name.is_empty());
            assert!(value.parse::<f64>().unwrap().is_finite(), "{line}");
            if name.starts_with("bs_latency_seconds_bucket") {
                let c = value.parse::<u64>().unwrap();
                assert!(c >= last_bucket, "cumulative buckets must be monotone: {line}");
                last_bucket = c;
            }
        }
        // `le` edges are increasing seconds values ending at +Inf.
        let les: Vec<&str> = text
            .lines()
            .filter(|l| l.starts_with("bs_latency_seconds_bucket"))
            .collect();
        assert!(les.len() > 2);
        assert!(les.last().unwrap().contains("le=\"+Inf\""));
    }
}
