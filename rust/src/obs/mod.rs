//! Observability: zero-overhead-when-disabled tracing + metrics over
//! the depth-first hot path.
//!
//! The subsystem follows the `fault` module's arming pattern exactly:
//! every instrumented site holds an `Option` — [`ObsCtx`] threaded
//! through [`crate::engine::Workload`] for spans,
//! `Option<&ObsCtx>` parameters through the CPU walker — and the
//! disarmed (`None`) branch touches no atomics, takes no locks and
//! allocates nothing, so an untraced run executes the pre-obs
//! instruction stream (asserted to within 1 % by
//! `benches/fig22_trace_drift.rs`).
//!
//! * [`span`] — per-thread-sharded span recording (Request → Batch →
//!   Plan → Segment → BranchArm → Band → Kernel) with a Chrome-trace
//!   (Perfetto) exporter; `brainslug trace` drives it.
//! * [`metrics`] — the shared 144-bucket [`Histogram`] (extracted from
//!   `ServerStats`), a labeled-series [`Registry`], and the Prometheus
//!   text exposition behind `GET /v1/metrics`.
//! * [`drift`] — predicted-vs-measured per-segment drift against
//!   [`crate::memsim::predicted_segments`] (`brainslug trace --drift`,
//!   fig22).
//!
//! The span-buffer drain-on-shutdown ordering is a real protocol:
//! writers record while a `recording` gate is open, shutdown closes
//! the gate, stops the writers, joins them, and only *then* drains —
//! [`flush_protocol`] is the model-checked replica
//! (`brainslug check --schedules`), and [`FlushBugs::drain_before_join`]
//! re-introduces the tempting wrong order (export first, stop later)
//! that loses late spans.

pub mod drift;
pub mod metrics;
pub mod span;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

pub use drift::{drift_report, DriftReport, DriftRow};
pub use metrics::{Exposition, Histogram, Registry, MIDPOINT_REL_ERROR};
pub use span::{chrome_trace, Span, SpanKind, SpanRecorder, ThreadSpans};

use crate::json::Json;

/// One observability domain: a span store and a metrics registry,
/// shared (`Arc<Obs>`) by everything that instruments one server or
/// one traced engine run.
#[derive(Debug, Default)]
pub struct Obs {
    pub spans: SpanRecorder,
    pub metrics: Registry,
}

impl Obs {
    /// Drain every recorded span into a Chrome-trace JSON document
    /// (the `trace.json` payload).
    pub fn drain_chrome_trace(&self) -> Json {
        let spans = self.spans.drain();
        chrome_trace(&spans, &self.spans.thread_names())
    }
}

/// The armed tracing context a backend run carries: the shared
/// [`Obs`] plus the request's trace id (0 when the run is not
/// attributed to a wire request). Cloned freely — two words.
#[derive(Debug, Clone)]
pub struct ObsCtx {
    pub obs: Arc<Obs>,
    pub trace: u64,
}

/// Parse an `x-brainslug-trace` header value: up to 16 hex digits.
pub fn parse_trace_id(value: &str) -> Option<u64> {
    let t = value.trim();
    if t.is_empty() || t.len() > 16 {
        return None;
    }
    u64::from_str_radix(t, 16).ok()
}

/// Generate the next trace id from a shared counter: a SplitMix64
/// draw, never 0 (0 means "unattributed" throughout the span layer).
pub fn next_trace_id(counter: &AtomicU64) -> u64 {
    let mut state = counter
        .fetch_add(1, Ordering::Relaxed)
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(0x1CE_B00DA);
    let id = crate::rng::splitmix64(&mut state);
    if id == 0 {
        1
    } else {
        id
    }
}

/// Declarative topology of the span flush protocol for the static lint
/// (`brainslug check`): writer threads record behind the `recording`
/// gate and quiesce on an `obs-stop` token; shutdown closes the gate,
/// sends one token per writer, then joins — draining only after the
/// join, which is what [`flush_protocol`] model-checks.
pub fn topology(writers: usize) -> crate::analysis::Topology {
    use crate::analysis::{ExitCondition, ShutdownStep, Topology};
    Topology::new("obs-flush")
        .gate("recording")
        .thread("span-writer", writers, ExitCondition::TokenOn("obs-stop".into()))
        .channel("obs-stop", writers, &["main"], &["span-writer"], Some("recording"))
        .on_shutdown(ShutdownStep::CloseGate("recording".into()))
        .on_shutdown(ShutdownStep::SendTokens {
            channel: "obs-stop".into(),
            count: writers,
        })
        .on_shutdown(ShutdownStep::Join("span-writer".into()))
}

/// Bug switches for [`flush_protocol`]. `Default` (all `false`) is the
/// shipped drain ordering.
#[derive(Debug, Clone, Copy, Default)]
pub struct FlushBugs {
    /// Drain the span buffer *before* stopping and joining the
    /// writers — the tempting "export what we have, then shut down"
    /// ordering. A writer that records between the drain and the gate
    /// close leaves its span in the buffer forever: an open obligation
    /// at join time, BSL056.
    pub drain_before_join: bool,
}

/// Model-checked replica of the span-buffer flush-on-shutdown
/// protocol: `writers` threads each record `spans_per_writer` spans
/// while the `recording` gate is open (each recorded span opens an
/// obligation that only the final drain completes), then quiesce on a
/// stop token. The shipped ordering — close the gate, stop and join
/// every writer, *then* drain — provably loses no recorded span;
/// [`FlushBugs::drain_before_join`] re-introduces the drop-on-drain
/// bug as a schedule-dependent BSL056 counterexample.
pub fn flush_protocol(writers: usize, spans_per_writer: usize, bugs: FlushBugs) {
    use crate::conc::sync::{model, sync_channel_labeled, Gate, Mutex};

    let ring = Arc::new(Mutex::labeled(Vec::<model::Obligation>::new(), "span-ring"));
    let gate = Arc::new(Gate::labeled("recording"));
    let (tx, rx) = sync_channel_labeled::<()>(writers, "obs-stop");
    tx.bind_gate(&gate);
    let rx = Arc::new(Mutex::labeled(rx, "obs-stop-rx"));

    let drain = |ring: &Mutex<Vec<model::Obligation>>| {
        let mut buf = match ring.lock() {
            Ok(b) => b,
            Err(_) => return,
        };
        for span in buf.drain(..) {
            span.complete();
        }
    };

    let mut pool = Vec::with_capacity(writers);
    for w in 0..writers {
        let ring = ring.clone();
        let gate = gate.clone();
        let rx = rx.clone();
        pool.push(model::spawn(&format!("span-writer-{w}"), move || {
            for i in 0..spans_per_writer {
                // A span is recorded only while the gate is open —
                // `ThreadSpans::record` against a drained recorder.
                if let Some(_recording) = gate.enter() {
                    if let Ok(mut buf) = ring.lock() {
                        buf.push(model::obligation(&format!("span-{w}-{i}")));
                    }
                }
            }
            // Quiesce: wait for the shutdown token before exiting.
            if let Ok(stop) = rx.lock() {
                let _ = stop.recv();
            }
        }));
    }

    if bugs.drain_before_join {
        // Seeded bug: export first, stop the writers later. Any span
        // recorded after the drain is never completed.
        drain(ring.as_ref());
        gate.close();
        for _ in 0..writers {
            let _ = tx.send_token(());
        }
        for h in pool {
            h.join();
        }
    } else {
        // Shipped ordering: no new spans (gate), no running writers
        // (tokens + join), then drain — every recorded span exported.
        gate.close();
        for _ in 0..writers {
            let _ = tx.send_token(());
        }
        for h in pool {
            h.join();
        }
        drain(ring.as_ref());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_ids_are_nonzero_and_distinct() {
        let counter = AtomicU64::new(0);
        let a = next_trace_id(&counter);
        let b = next_trace_id(&counter);
        assert_ne!(a, 0);
        assert_ne!(b, 0);
        assert_ne!(a, b);
    }

    #[test]
    fn trace_header_parsing() {
        assert_eq!(parse_trace_id("00000000deadbeef"), Some(0xDEAD_BEEF));
        assert_eq!(parse_trace_id("1"), Some(1));
        assert_eq!(parse_trace_id(" ff "), Some(255));
        assert_eq!(parse_trace_id(""), None);
        assert_eq!(parse_trace_id("not-hex"), None);
        assert_eq!(parse_trace_id("00000000000000000"), None, "17 digits overflow");
    }

    #[test]
    fn flush_protocol_smoke_outside_the_model() {
        // Outside the model checker the facade is plain std::sync: both
        // orderings must at least run to completion (the *bug* is only
        // observable as an open obligation, which the model layer
        // tracks).
        flush_protocol(2, 2, FlushBugs::default());
    }

    #[test]
    fn obs_domain_collects_spans_and_metrics_together() {
        let obs = Obs::default();
        let ts = obs.spans.thread("t");
        ts.record(SpanKind::Plan, "plan", 0, std::time::Instant::now());
        obs.metrics.histogram("seg_seconds", "h", "segment", "seg0").record(100);
        let doc = obs.drain_chrome_trace();
        assert_eq!(doc.arr_field("traceEvents").unwrap().len(), 2, "metadata + span");
        assert_eq!(obs.metrics.series_count(), 1);
    }
}
