//! Predicted-vs-measured drift: join recorded [`SpanKind::Segment`]
//! spans against [`crate::memsim::predicted_segments`] and report how
//! far the analytic cost model is from measured per-segment wall-clock.
//!
//! The join key is the segment label prefix: the native CPU backend
//! labels its top-level segment spans `seg{i}:{kind}` and memsim
//! predicts `seg{i}`, so every top-level segment of a plan appears in
//! the report by construction. The measured side takes the *minimum*
//! duration across runs (the standard noise floor for wall-clock
//! micro-measurement, same as `bench::measure`); the ratio column is
//! `measured / predicted`, and the Spearman rank correlation says
//! whether the model at least orders segments correctly — the property
//! the planner and autotuner pre-pass actually rely on.

use std::collections::BTreeMap;

use crate::json::Json;
use crate::memsim::SegmentPrediction;

use super::span::{Span, SpanKind};

/// One segment's predicted-vs-measured row.
#[derive(Debug, Clone)]
pub struct DriftRow {
    /// Join key (`seg{i}`).
    pub segment: String,
    /// Segment flavor from the prediction (`stack`, `branch`, or a
    /// layer kind).
    pub kind: String,
    pub predicted_s: f64,
    /// Minimum measured duration across runs; 0.0 when no span matched
    /// (counted in [`DriftReport::unmatched`]).
    pub measured_s: f64,
    /// `measured / predicted` (0.0 when either side is missing).
    pub ratio: f64,
}

/// The drift report for one network.
#[derive(Debug, Clone)]
pub struct DriftReport {
    pub network: String,
    /// One row per predicted top-level segment, in plan order.
    pub rows: Vec<DriftRow>,
    /// Spearman rank correlation between predicted and measured times
    /// (1.0 for fewer than two matched rows, where ordering is vacuous).
    pub rank_correlation: f64,
    /// Predicted segments with no measured span (0 for a complete
    /// trace).
    pub unmatched: usize,
}

/// Build the drift report for `network` from memsim predictions and a
/// drained span buffer.
pub fn drift_report(network: &str, predicted: &[SegmentPrediction], spans: &[Span]) -> DriftReport {
    // Min duration per segment label prefix across all runs.
    let mut measured: BTreeMap<&str, u64> = BTreeMap::new();
    for s in spans {
        if s.kind != SpanKind::Segment {
            continue;
        }
        let key = s.label.split(':').next().unwrap_or(&s.label);
        measured
            .entry(key)
            .and_modify(|d| *d = (*d).min(s.dur_ns))
            .or_insert(s.dur_ns);
    }
    let mut unmatched = 0usize;
    let rows: Vec<DriftRow> = predicted
        .iter()
        .map(|p| {
            let measured_s = match measured.get(p.label.as_str()) {
                Some(&ns) => ns as f64 / 1e9,
                None => {
                    unmatched += 1;
                    0.0
                }
            };
            let ratio = if p.seconds > 0.0 && measured_s > 0.0 {
                measured_s / p.seconds
            } else {
                0.0
            };
            DriftRow {
                segment: p.label.clone(),
                kind: p.kind.to_string(),
                predicted_s: p.seconds,
                measured_s,
                ratio,
            }
        })
        .collect();
    let matched: Vec<(f64, f64)> = rows
        .iter()
        .filter(|r| r.measured_s > 0.0)
        .map(|r| (r.predicted_s, r.measured_s))
        .collect();
    DriftReport {
        network: network.to_string(),
        rank_correlation: spearman(&matched),
        rows,
        unmatched,
    }
}

/// Ordinal ranks of `values` (ties broken by index — measured times
/// are wall-clock f64s, so exact ties are not a practical concern).
fn ranks(values: &[f64]) -> Vec<f64> {
    let mut order: Vec<usize> = (0..values.len()).collect();
    order.sort_by(|&a, &b| values[a].total_cmp(&values[b]));
    let mut out = vec![0.0; values.len()];
    for (rank, &idx) in order.iter().enumerate() {
        out[idx] = rank as f64;
    }
    out
}

/// Spearman rank correlation of (predicted, measured) pairs; 1.0 for
/// fewer than two pairs (ordering is vacuously preserved).
fn spearman(pairs: &[(f64, f64)]) -> f64 {
    let n = pairs.len();
    if n < 2 {
        return 1.0;
    }
    let pred: Vec<f64> = pairs.iter().map(|p| p.0).collect();
    let meas: Vec<f64> = pairs.iter().map(|p| p.1).collect();
    let rp = ranks(&pred);
    let rm = ranks(&meas);
    let mean = (n as f64 - 1.0) / 2.0;
    let mut cov = 0.0;
    let mut var_p = 0.0;
    let mut var_m = 0.0;
    for i in 0..n {
        let dp = rp[i] - mean;
        let dm = rm[i] - mean;
        cov += dp * dm;
        var_p += dp * dp;
        var_m += dm * dm;
    }
    if var_p == 0.0 || var_m == 0.0 {
        return 1.0;
    }
    cov / (var_p.sqrt() * var_m.sqrt())
}

impl DriftReport {
    /// Machine-readable form: one object per segment plus the summary
    /// fields — the rows `fig22_trace_drift` emits.
    pub fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("network", Json::Str(self.network.clone()));
        o.set("rank_correlation", Json::Num(self.rank_correlation));
        o.set("unmatched", Json::from_usize(self.unmatched));
        o.set(
            "segments",
            Json::Arr(
                self.rows
                    .iter()
                    .map(|r| {
                        let mut s = Json::object();
                        s.set("segment", Json::Str(r.segment.clone()));
                        s.set("kind", Json::Str(r.kind.clone()));
                        s.set("predicted_s", Json::Num(r.predicted_s));
                        s.set("measured_s", Json::Num(r.measured_s));
                        s.set("ratio", Json::Num(r.ratio));
                        s
                    })
                    .collect(),
            ),
        );
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pred(label: &str, seconds: f64) -> SegmentPrediction {
        SegmentPrediction {
            label: label.to_string(),
            kind: "stack",
            seconds,
        }
    }

    fn seg_span(label: &str, dur_ns: u64) -> Span {
        Span {
            kind: SpanKind::Segment,
            label: label.to_string(),
            trace: 0,
            tid: 0,
            start_ns: 0,
            dur_ns,
        }
    }

    #[test]
    fn joins_on_label_prefix_with_min_across_runs() {
        let predicted = vec![pred("seg0", 1e-3), pred("seg1", 2e-3)];
        let spans = vec![
            seg_span("seg0:stack", 3_000_000),
            seg_span("seg0:stack", 2_000_000), // second run, faster
            seg_span("seg1:branch", 4_000_000),
            Span {
                kind: SpanKind::Kernel,
                ..seg_span("seg0:stack", 1) // non-segment spans are ignored
            },
        ];
        let report = drift_report("vgg16", &predicted, &spans);
        assert_eq!(report.unmatched, 0);
        assert_eq!(report.rows.len(), 2);
        assert!((report.rows[0].measured_s - 2e-3).abs() < 1e-12, "min across runs");
        assert!((report.rows[0].ratio - 2.0).abs() < 1e-9);
        assert!((report.rows[1].measured_s - 4e-3).abs() < 1e-12);
        // Both sides order seg0 < seg1: perfect rank agreement.
        assert!((report.rank_correlation - 1.0).abs() < 1e-9);
    }

    #[test]
    fn unmatched_segments_are_counted_not_dropped() {
        let predicted = vec![pred("seg0", 1e-3), pred("seg1", 2e-3)];
        let spans = vec![seg_span("seg0:stack", 1_000_000)];
        let report = drift_report("net", &predicted, &spans);
        assert_eq!(report.rows.len(), 2, "every predicted segment keeps a row");
        assert_eq!(report.unmatched, 1);
        assert_eq!(report.rows[1].measured_s, 0.0);
        assert_eq!(report.rows[1].ratio, 0.0);
    }

    #[test]
    fn anticorrelated_ordering_is_negative() {
        let predicted = vec![pred("seg0", 1e-3), pred("seg1", 2e-3), pred("seg2", 3e-3)];
        let spans = vec![
            seg_span("seg0:stack", 3_000_000),
            seg_span("seg1:stack", 2_000_000),
            seg_span("seg2:stack", 1_000_000),
        ];
        let report = drift_report("net", &predicted, &spans);
        assert!((report.rank_correlation + 1.0).abs() < 1e-9, "{}", report.rank_correlation);
    }

    #[test]
    fn json_shape_round_trips() {
        let predicted = vec![pred("seg0", 1e-3)];
        let spans = vec![seg_span("seg0:stack", 1_500_000)];
        let j = drift_report("resnet18", &predicted, &spans).to_json();
        let parsed = crate::json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(parsed.str_field("network").unwrap(), "resnet18");
        let segs = parsed.arr_field("segments").unwrap();
        assert_eq!(segs.len(), 1);
        assert!(segs[0].f64_field("ratio").unwrap() > 0.0);
        assert!(segs[0].f64_field("predicted_s").unwrap() > 0.0);
    }
}
