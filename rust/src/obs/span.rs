//! Span recording over the depth-first hot path.
//!
//! A [`SpanRecorder`] holds one bounded buffer *per recording thread*
//! (sharded by `ThreadId`), so the hot path — one uncontended lock and
//! a `Vec` push on the recording thread's own shard — never contends
//! with other workers or with the exporter. A thread's server-side
//! spans (Request/Batch) and its backend spans (Plan/Segment/Band/
//! Kernel) share one shard and therefore one timeline row, which is
//! what makes the Chrome-trace export nest them visually.
//!
//! Buffers are bounded (default 65 536 spans per thread): past the cap
//! new spans are counted in [`SpanRecorder::dropped`] instead of
//! growing without bound — a tracing layer must never become the
//! memory leak it was meant to find. [`SpanRecorder::drain`] takes the
//! accumulated spans (sorted by start time) for export; the drain
//! ordering contract against in-flight writers is model-checked by
//! [`crate::obs::flush_protocol`].

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::ThreadId;
use std::time::Instant;

use crate::json::Json;

/// What a span measures — one row of the span taxonomy (see
/// DESIGN.md §Observability). Ordered outermost to innermost.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// One client request, enqueue to reply (`server::batch_loop`).
    Request,
    /// One gathered batch execution, gather-exit to scatter.
    Batch,
    /// One full plan (or baseline) execution on a backend.
    Plan,
    /// One top-level plan segment (`Single`/`Stack`/`Branch`).
    Segment,
    /// One branch arm inside a `Branch` segment.
    BranchArm,
    /// One depth-first band (rows of one plane through a sequence).
    Band,
    /// One native kernel dispatch (`cpu::backend::run_node`).
    Kernel,
}

impl SpanKind {
    pub const ALL: [SpanKind; 7] = [
        SpanKind::Request,
        SpanKind::Batch,
        SpanKind::Plan,
        SpanKind::Segment,
        SpanKind::BranchArm,
        SpanKind::Band,
        SpanKind::Kernel,
    ];

    /// Stable lowercase name — the Chrome-trace `cat` field and the
    /// `trace` summary's per-kind counts.
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Request => "request",
            SpanKind::Batch => "batch",
            SpanKind::Plan => "plan",
            SpanKind::Segment => "segment",
            SpanKind::BranchArm => "branch-arm",
            SpanKind::Band => "band",
            SpanKind::Kernel => "kernel",
        }
    }
}

/// One recorded span: a kind, a human label, the request's trace id
/// (0 when unattributed), and a `[start, start+dur)` interval in
/// nanoseconds since the recorder's epoch.
#[derive(Debug, Clone)]
pub struct Span {
    pub kind: SpanKind,
    pub label: String,
    pub trace: u64,
    /// Dense per-recorder thread ordinal (the Chrome-trace `tid`).
    pub tid: u64,
    pub start_ns: u64,
    pub dur_ns: u64,
}

#[derive(Debug)]
struct Shard {
    tid: u64,
    spans: Mutex<Vec<Span>>,
}

/// Default per-thread span capacity.
const DEFAULT_CAPACITY: usize = 65_536;

/// The sharded span store. Cheap to create; all recording goes through
/// per-thread [`ThreadSpans`] handles obtained from [`Self::thread`].
#[derive(Debug)]
pub struct SpanRecorder {
    epoch: Instant,
    shards: Mutex<HashMap<ThreadId, Arc<Shard>>>,
    names: Mutex<BTreeMap<u64, String>>,
    next_tid: AtomicU64,
    dropped: Arc<AtomicU64>,
    capacity: usize,
}

impl Default for SpanRecorder {
    fn default() -> Self {
        SpanRecorder::with_capacity(DEFAULT_CAPACITY)
    }
}

impl SpanRecorder {
    /// A recorder whose per-thread buffers hold at most `capacity`
    /// spans (further spans are dropped and counted).
    pub fn with_capacity(capacity: usize) -> Self {
        SpanRecorder {
            epoch: Instant::now(),
            shards: Mutex::new(HashMap::new()),
            names: Mutex::new(BTreeMap::new()),
            next_tid: AtomicU64::new(0),
            dropped: Arc::new(AtomicU64::new(0)),
            capacity: capacity.max(1),
        }
    }

    /// The calling thread's recording handle (created on first call,
    /// shared across calls from the same thread). `fallback` names the
    /// timeline row when the thread itself is unnamed.
    pub fn thread(&self, fallback: &str) -> ThreadSpans {
        let id = std::thread::current().id();
        let shard = {
            let mut shards = self.shards.lock().unwrap_or_else(|p| p.into_inner());
            shards
                .entry(id)
                .or_insert_with(|| {
                    let tid = self.next_tid.fetch_add(1, Ordering::Relaxed);
                    let name = std::thread::current()
                        .name()
                        .map(str::to_string)
                        .unwrap_or_else(|| fallback.to_string());
                    let mut names = self.names.lock().unwrap_or_else(|p| p.into_inner());
                    names.insert(tid, name);
                    Arc::new(Shard {
                        tid,
                        spans: Mutex::new(Vec::new()),
                    })
                })
                .clone()
        };
        ThreadSpans {
            shard,
            epoch: self.epoch,
            capacity: self.capacity,
            dropped: self.dropped.clone(),
        }
    }

    /// Take every recorded span, sorted by start time. Shards whose
    /// threads still hold a [`ThreadSpans`] handle stay registered (and
    /// keep their timeline row); abandoned shards are evicted.
    pub fn drain(&self) -> Vec<Span> {
        let mut out = Vec::new();
        let mut shards = self.shards.lock().unwrap_or_else(|p| p.into_inner());
        shards.retain(|_, shard| {
            let mut spans = shard.spans.lock().unwrap_or_else(|p| p.into_inner());
            out.append(&mut spans);
            drop(spans);
            Arc::strong_count(shard) > 1
        });
        drop(shards);
        out.sort_by_key(|s| s.start_ns);
        out
    }

    /// Spans discarded because a thread's buffer was full.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Timeline-row names, keyed by the dense `tid` ordinal.
    pub fn thread_names(&self) -> BTreeMap<u64, String> {
        self.names.lock().unwrap_or_else(|p| p.into_inner()).clone()
    }
}

/// One thread's recording handle: an `Arc` to its own shard plus the
/// recorder's epoch. Recording locks only this thread's shard, so the
/// hot path is uncontended (the exporter takes the same lock only
/// during a drain).
#[derive(Debug, Clone)]
pub struct ThreadSpans {
    shard: Arc<Shard>,
    epoch: Instant,
    capacity: usize,
    dropped: Arc<AtomicU64>,
}

impl ThreadSpans {
    /// Close a span opened at `started` (an `Instant::now()` taken
    /// before the measured work) and record it.
    pub fn record(&self, kind: SpanKind, label: &str, trace: u64, started: Instant) {
        let end = Instant::now();
        let start_ns = started.saturating_duration_since(self.epoch).as_nanos() as u64;
        let dur_ns = end.saturating_duration_since(started).as_nanos() as u64;
        let mut spans = self.shard.spans.lock().unwrap_or_else(|p| p.into_inner());
        if spans.len() >= self.capacity {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        spans.push(Span {
            kind,
            label: label.to_string(),
            trace,
            tid: self.shard.tid,
            start_ns,
            dur_ns,
        });
    }
}

/// Render spans as a Chrome-trace (Perfetto / `chrome://tracing`) JSON
/// document: complete (`"ph": "X"`) events with microsecond `ts`/`dur`,
/// one `pid`, per-recorder-thread `tid` rows named via `thread_name`
/// metadata events, and the trace id (16 hex digits) in `args`.
pub fn chrome_trace(spans: &[Span], thread_names: &BTreeMap<u64, String>) -> Json {
    let mut events = Vec::with_capacity(spans.len() + thread_names.len());
    for (tid, name) in thread_names {
        let mut args = Json::object();
        args.set("name", Json::Str(name.clone()));
        let mut m = Json::object();
        m.set("name", Json::Str("thread_name".into()));
        m.set("ph", Json::Str("M".into()));
        m.set("pid", Json::from_usize(1));
        m.set("tid", Json::Num(*tid as f64));
        m.set("args", args);
        events.push(m);
    }
    for s in spans {
        let mut args = Json::object();
        args.set("trace", Json::Str(format!("{:016x}", s.trace)));
        let mut e = Json::object();
        e.set("name", Json::Str(s.label.clone()));
        e.set("cat", Json::Str(s.kind.name().into()));
        e.set("ph", Json::Str("X".into()));
        e.set("ts", Json::Num(s.start_ns as f64 / 1000.0));
        e.set("dur", Json::Num(s.dur_ns as f64 / 1000.0));
        e.set("pid", Json::from_usize(1));
        e.set("tid", Json::Num(s.tid as f64));
        e.set("args", args);
        events.push(e);
    }
    let mut doc = Json::object();
    doc.set("traceEvents", Json::Arr(events));
    doc.set("displayTimeUnit", Json::Str("ms".into()));
    doc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_share_a_shard_per_thread_and_nest() {
        let rec = SpanRecorder::default();
        let ts = rec.thread("outer");
        let t0 = Instant::now();
        std::thread::sleep(std::time::Duration::from_millis(1));
        let inner0 = Instant::now();
        ts.record(SpanKind::Kernel, "conv0", 7, inner0);
        ts.record(SpanKind::Segment, "seg0:stack", 7, t0);
        // Same thread → same tid, so Perfetto nests them on one row.
        let spans = rec.drain();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].tid, spans[1].tid);
        // Sorted by start: the enclosing segment starts first.
        assert_eq!(spans[0].kind, SpanKind::Segment);
        assert!(spans[0].start_ns <= spans[1].start_ns);
        assert!(spans[0].dur_ns >= 1_000_000, "slept 1ms inside the segment");
        assert_eq!(spans[0].trace, 7);
    }

    #[test]
    fn distinct_threads_get_distinct_named_rows() {
        let rec = Arc::new(SpanRecorder::default());
        let main_ts = rec.thread("main");
        main_ts.record(SpanKind::Plan, "plan", 1, Instant::now());
        let rec2 = rec.clone();
        std::thread::spawn(move || {
            let ts = rec2.thread("band-worker");
            ts.record(SpanKind::Band, "p0:r0", 1, Instant::now());
        })
        .join()
        .unwrap();
        let spans = rec.drain();
        assert_eq!(spans.len(), 2);
        assert_ne!(spans[0].tid, spans[1].tid);
        let names = rec.thread_names();
        assert_eq!(names.len(), 2);
        assert!(names.values().any(|n| n == "band-worker"), "{names:?}");
    }

    #[test]
    fn capacity_bounds_the_buffer_and_counts_drops() {
        let rec = SpanRecorder::with_capacity(4);
        let ts = rec.thread("t");
        for i in 0..10 {
            ts.record(SpanKind::Kernel, &format!("k{i}"), 0, Instant::now());
        }
        assert_eq!(rec.drain().len(), 4);
        assert_eq!(rec.dropped(), 6);
        // Post-drain the buffer has room again.
        ts.record(SpanKind::Kernel, "after", 0, Instant::now());
        assert_eq!(rec.drain().len(), 1);
    }

    #[test]
    fn chrome_export_shape() {
        let rec = SpanRecorder::default();
        let ts = rec.thread("main");
        ts.record(SpanKind::Request, "req", 0xDEAD_BEEF, Instant::now());
        let doc = chrome_trace(&rec.drain(), &rec.thread_names());
        let text = doc.to_string_compact();
        // Round-trips through our own parser with the trace-viewer
        // contract intact: one metadata event, one complete event.
        let parsed = crate::json::parse(&text).unwrap();
        assert_eq!(parsed.str_field("displayTimeUnit").unwrap(), "ms");
        let events = parsed.arr_field("traceEvents").unwrap();
        assert_eq!(events.len(), 2);
        let phs: Vec<String> = events.iter().filter_map(|e| e.str_field("ph").ok()).collect();
        assert!(phs.iter().any(|p| p == "M") && phs.iter().any(|p| p == "X"), "{phs:?}");
        let x = events
            .iter()
            .find(|e| e.str_field("ph").is_ok_and(|p| p == "X"))
            .unwrap();
        assert_eq!(x.str_field("cat").unwrap(), "request");
        assert!(x.f64_field("ts").is_ok() && x.f64_field("dur").is_ok());
        let args = x.get("args").unwrap();
        assert_eq!(args.str_field("trace").unwrap(), "00000000deadbeef");
    }
}
